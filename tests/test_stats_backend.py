"""Device-resident statistics engine: kernel/host parity, chunked-partials
merge laws, backend-selectable streaming aggregation, and the streaming
paired-delta bootstrap (ISSUE 4 tentpole)."""

import warnings

import numpy as np
import pytest

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    StatisticsConfig,
    compare_stream_stats,
)
from repro.data import iter_qa_examples
from repro.ft import ChunkCrashMiddleware, Fault, SimulatedCrash
from repro.kernels.bootstrap import (
    bootstrap_means_ref,
    bootstrap_partials,
)
from repro.stats import (
    MetricAccumulator,
    PallasBootstrapEngine,
    bootstrap_engine_from_state,
    make_bootstrap_engine,
    replicate_p_value,
    streaming_ci,
)

M_A = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")
M_B = EngineModelConfig(provider="anthropic", model_name="claude-3-haiku")


def _task(task_id="stream", backend="pallas", n_boot=200, **stream_kw):
    return EvalTask(
        task_id=task_id,
        model=M_A,
        inference=InferenceConfig(batch_size=32, n_workers=2, cache_dir=""),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=n_boot, ci_method="percentile",
            backend=backend,
        ),
    ).with_streaming(**stream_kw)


def _scores(n=500, m=3, nan_every=13, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.random((n, m))
    if nan_every:
        x[::nan_every, min(1, m - 1)] = np.nan
    return x


# -- kernel / ref parity -------------------------------------------------------


def test_partials_ref_reduces_to_means_path():
    """start=0, single NaN-free metric: partials must reproduce the
    original means kernel's weight stream exactly."""
    import jax.numpy as jnp

    x = _scores(400, 1, nan_every=0)
    swx, sw = bootstrap_partials(x, 42, 0, n_boot=64, mode="ref")
    means = swx[:, 0] / np.maximum(sw[:, 0], 1.0)
    ref = np.asarray(
        bootstrap_means_ref(jnp.asarray(x[:, 0], jnp.float32), 64, 42)
    )
    np.testing.assert_allclose(means, ref, rtol=1e-5)


@pytest.mark.parametrize("n,m,start", [(300, 2, 0), (500, 3, 1024), (70, 1, 7)])
def test_partials_kernel_interpret_matches_ref(n, m, start):
    x = _scores(n, m, nan_every=11, seed=n)
    k_swx, k_sw = bootstrap_partials(x, 9, start, n_boot=64, mode="interpret")
    r_swx, r_sw = bootstrap_partials(x, 9, start, n_boot=64, mode="ref")
    np.testing.assert_allclose(k_swx, r_swx, rtol=2e-5, atol=1e-3)
    np.testing.assert_allclose(k_sw, r_sw, rtol=2e-5, atol=1e-3)


def test_partials_nan_weight_exclusion():
    """NaN scores carry zero weight for that metric only."""
    x = np.array([[1.0, np.nan], [2.0, 5.0], [3.0, np.nan]])
    swx, sw = bootstrap_partials(x, 3, 0, n_boot=32, mode="ref")
    # metric 1 only ever sees example 1: every replicate mean is 5 (or
    # empty when example 1 drew weight 0)
    nonzero = sw[:, 1] > 0
    np.testing.assert_allclose(swx[nonzero, 1] / sw[nonzero, 1], 5.0)
    assert (sw[:, 1] <= sw[:, 0]).all()


def test_partials_merge_law_partition_and_permutation():
    """Weights are keyed by absolute position: partials over any chunking,
    merged in any order, give the same replicates (float tolerance — the
    summation order differs across partitions)."""
    x = _scores(700, 2, nan_every=9)
    full_swx, full_sw = bootstrap_partials(x, 5, 0, n_boot=128, mode="ref")
    full = full_swx.astype(np.float64) / np.maximum(
        full_sw.astype(np.float64), 1.0
    )
    rng = np.random.default_rng(1)
    for _ in range(3):
        cuts = sorted(rng.choice(np.arange(1, 700), size=4, replace=False))
        bounds = [0, *cuts, 700]
        parts = [
            (lo, bootstrap_partials(x[lo:hi], 5, lo, n_boot=128, mode="ref"))
            for lo, hi in zip(bounds, bounds[1:])
        ]
        rng.shuffle(parts)  # merge order must not matter
        swx = np.zeros((128, 2), np.float64)
        sw = np.zeros((128, 2), np.float64)
        for _, (pswx, psw) in parts:
            swx += pswx
            sw += psw
        np.testing.assert_allclose(
            swx / np.maximum(sw, 1.0), full, rtol=1e-4, atol=1e-6
        )


def test_partials_identical_layout_bitwise_deterministic():
    """Same chunk layout -> bit-identical partials (the crash/resume
    guarantee rests on this)."""
    x = _scores(600, 2)
    for mode in ("ref", "interpret"):
        a = bootstrap_partials(x[:256], 5, 0, n_boot=64, mode=mode)
        b = bootstrap_partials(x[:256], 5, 0, n_boot=64, mode=mode)
        assert (a[0] == b[0]).all() and (a[1] == b[1]).all()


# -- engines -------------------------------------------------------------------


def _fill_engine(engine, scores_by_metric, chunk=200):
    n = len(next(iter(scores_by_metric.values())))
    for lo in range(0, n, chunk):
        part = engine.spawn()
        part.update(
            {m: v[lo:lo + chunk] for m, v in scores_by_metric.items()}, lo
        )
        engine.merge(part)
    return engine


def test_numpy_engine_bit_identical_to_per_metric_poisson_bootstrap():
    """The engine draws the shared Philox block once and masks per metric;
    results must equal M independent PoissonBootstrap updates bit-for-bit
    (spill states from older runs of the same stream stay mergeable)."""
    from repro.stats import PoissonBootstrap

    rng = np.random.default_rng(11)
    scores = {"a": rng.random(300), "b": rng.random(300)}
    scores["b"][::7] = np.nan
    engine = _fill_engine(
        make_bootstrap_engine("numpy", 64, 5, ("a", "b")), scores, chunk=128
    )
    for j, m in enumerate(("a", "b")):
        boot = PoissonBootstrap(64, seed=5)
        for lo in range(0, 300, 128):
            boot.update(scores[m][lo:lo + 128], lo)
        assert (engine.sum_wx[:, j] == boot.sum_wx).all()
        assert (engine.sum_w[:, j] == boot.sum_w).all()


def test_pallas_engine_ci_within_mc_tolerance_of_numpy():
    """Kernel counter-mixer stream vs host Philox stream: different RNGs,
    same statistics — CI endpoints agree within Monte-Carlo noise."""
    rng = np.random.default_rng(4)
    scores = {"m": rng.random(1200)}
    acc = MetricAccumulator()
    acc.update(scores["m"])
    ivs = {}
    for backend in ("numpy", "pallas"):
        engine = _fill_engine(
            make_bootstrap_engine(backend, 1000, 0, ("m",)), scores
        )
        ivs[backend] = streaming_ci(acc, engine.view("m"), method="percentile")
    width = ivs["numpy"].hi - ivs["numpy"].lo
    assert ivs["pallas"].lo == pytest.approx(ivs["numpy"].lo, abs=0.5 * width)
    assert ivs["pallas"].hi == pytest.approx(ivs["numpy"].hi, abs=0.5 * width)


def test_pallas_interpret_engine_matches_cpu_stream():
    """interpret=True kernel through the engine == the blocked jnp oracle
    (same weight stream bit-for-bit)."""
    rng = np.random.default_rng(6)
    scores = {"a": rng.random(300), "b": rng.random(300)}

    class InterpretEngine(PallasBootstrapEngine):
        mode = "interpret"

    ref = _fill_engine(
        PallasBootstrapEngine(64, 3, ("a", "b")), scores, chunk=128
    )
    interp = _fill_engine(
        InterpretEngine(64, 3, ("a", "b")), scores, chunk=128
    )
    np.testing.assert_allclose(ref.sum_wx, interp.sum_wx, rtol=2e-6)
    np.testing.assert_allclose(ref.sum_w, interp.sum_w, rtol=2e-6)


def test_engine_state_roundtrip_and_merge_guards():
    rng = np.random.default_rng(5)
    scores = {"a": rng.random(256), "b": rng.random(256)}
    engine = _fill_engine(
        make_bootstrap_engine("pallas", 64, 1, ("a", "b")), scores, chunk=100
    )
    clone = bootstrap_engine_from_state(engine.state())
    assert (clone.sum_wx == engine.sum_wx).all()
    assert (clone.sum_w == engine.sum_w).all()
    with pytest.raises(ValueError, match="cannot merge"):
        engine.merge(make_bootstrap_engine("numpy", 64, 1, ("a", "b")))
    with pytest.raises(ValueError, match="cannot merge"):
        engine.merge(make_bootstrap_engine("pallas", 64, 2, ("a", "b")))
    with pytest.raises(ValueError, match="unknown statistics backend"):
        make_bootstrap_engine("cuda", 64, 1, ("a",))


def test_merge_state_rejects_cross_stream_partials():
    """A spill written by the TPU kernel must not resume float-inexactly
    through the CPU oracle (and vice versa)."""
    engine = PallasBootstrapEngine(32, 0, ("a",))
    state = engine.spawn().state()
    assert state["stream"] == "pallas-ref"  # CPU test environment
    state["stream"] = "pallas-kernel"       # as if written on a TPU host
    with pytest.raises(ValueError, match="cannot merge"):
        engine.merge_state(state)


def test_resume_cross_platform_partials_raise_manifest_mismatch():
    """The designed cross-platform resume refusal surfaces as the
    documented non-reusable-spill error, not a bare ValueError."""
    from repro.core import ManifestMismatch
    from repro.core.streaming import StreamingPipeline

    engine = PallasBootstrapEngine(16, 0, ("a",))
    state = engine.spawn().state()
    state["stream"] = "pallas-kernel"  # spilled on a TPU host
    acc = MetricAccumulator()
    acc.update(np.ones(4))
    row = {"metrics": {"a": acc.state()}, "boot": state}
    with pytest.raises(ManifestMismatch, match="platform that wrote"):
        StreamingPipeline._merge_committed(
            row, {"a": MetricAccumulator()}, engine, [], {},
            {"calls": 0, "total_cost": 0.0, "pool": {}}, {},
        )


def test_partials_empty_chunk_returns_zero_partials():
    for mode in ("ref", "interpret"):
        swx, sw = bootstrap_partials(
            np.zeros((0, 2)), 0, 0, n_boot=16, mode=mode
        )
        assert swx.shape == (16, 2)
        assert not swx.any() and not sw.any()


def test_replicate_p_value_extremes():
    assert replicate_p_value(np.full(99, 3.0)) == pytest.approx(0.02)
    assert replicate_p_value(np.array([])) == 1.0
    sym = np.concatenate([np.arange(-50, 0), np.arange(1, 51)])
    assert replicate_p_value(sym) > 0.9


# -- streaming pipeline integration --------------------------------------------


def test_streaming_run_pallas_backend_matches_numpy_within_tolerance():
    results = {}
    for backend in ("numpy", "pallas"):
        with EvalSession() as session:
            results[backend] = session.run_task(
                iter_qa_examples(400, seed=3),
                _task(backend=backend, n_boot=500, max_memory_rows=128),
            )
    for m in ("exact_match", "token_f1"):
        nv, pv = results["numpy"].metrics[m], results["pallas"].metrics[m]
        assert pv.value == pytest.approx(nv.value, abs=1e-12)  # exact mean
        width = max(nv.ci[1] - nv.ci[0], 1e-6)
        assert pv.ci[0] == pytest.approx(nv.ci[0], abs=0.75 * width)
        assert pv.ci[1] == pytest.approx(nv.ci[1], abs=0.75 * width)
    log = results["pallas"].logs["streaming"]
    assert log["stats_backend"] == "pallas"
    ss = results["pallas"].stream_stats
    assert ss is not None and ss.engine.backend == "pallas"
    assert ss.n_examples == 400


def test_concurrent_executor_pallas_backend_bit_identical_to_serial():
    """Chunk workers drive the jitted partials path from several threads;
    ordered merging must still reproduce the serial bytes."""
    with EvalSession() as session:
        serial = session.run_task(
            iter_qa_examples(300, seed=8),
            _task(backend="pallas", max_memory_rows=64),
        )
    with EvalSession() as session:
        conc = session.run_task(
            iter_qa_examples(300, seed=8),
            _task(backend="pallas", max_memory_rows=64, concurrency=3),
        )
    for m, mv in serial.metrics.items():
        assert conc.metrics[m].value == mv.value
        assert conc.metrics[m].ci == mv.ci
    assert (
        conc.stream_stats.engine.sum_wx == serial.stream_stats.engine.sum_wx
    ).all()


def test_streaming_suite_paired_comparison_resolves_small_diff():
    """The paired-delta CI must be far tighter than the per-model CIs —
    that is the entire value of sharing weight streams."""
    task = _task(backend="pallas", n_boot=400, max_memory_rows=64)
    suite = (
        EvalSuite("paired")
        .add_task(task, lambda: iter_qa_examples(300, seed=12))
        .sweep_models([M_A, M_B])
    )
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # no opt-out, no incompatibility
        with EvalSession() as session:
            res = session.run_suite(suite)
    cmp = res.comparison("stream", "token_f1", *res.models)
    assert cmp.test.test == "paired_bootstrap"
    assert cmp.diff_ci[0] <= cmp.diff <= cmp.diff_ci[1]
    ra = res.result(res.models[0], "stream")
    per_model_width = (
        ra.metrics["token_f1"].ci[1] - ra.metrics["token_f1"].ci[0]
    )
    assert (cmp.diff_ci[1] - cmp.diff_ci[0]) < per_model_width


def test_compare_stream_stats_rejects_mismatched_streams():
    with EvalSession() as session:
        r1 = session.run_task(
            iter_qa_examples(200, seed=3),
            _task(backend="pallas", max_memory_rows=64),
        )
    with EvalSession() as session:
        r2 = session.run_task(
            iter_qa_examples(200, seed=3),
            _task(backend="numpy", max_memory_rows=64),
        )
    reason = r1.stream_stats.comparable_with(r2.stream_stats)
    assert reason is not None and "streams differ" in reason
    with pytest.raises(ValueError, match="not paired-comparable"):
        compare_stream_stats("token_f1", r1.stream_stats, r2.stream_stats)


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_paired_comparison_bit_identical_across_resume(tmp_path, backend):
    """Acceptance: a crash-resumed two-model streaming suite reproduces the
    uninterrupted Comparison matrix bit-for-bit."""
    def suite_for(spill):
        task = _task(
            backend=backend, n_boot=200, max_memory_rows=50,
            spill_dir=str(spill),
        )
        return (
            EvalSuite("resume")
            .add_task(task, lambda: iter_qa_examples(250, seed=9))
            .sweep_models([M_A, M_B])
        )

    with EvalSession() as session:
        ref = session.run_suite(suite_for(tmp_path / "ref"))

    crash = ChunkCrashMiddleware([Fault(shard=2, attempt=1)])
    with EvalSession(middleware=[crash]) as session:
        with pytest.raises(SimulatedCrash):
            session.run_suite(suite_for(tmp_path / "run"))
    with EvalSession() as session:
        res = session.run_suite(suite_for(tmp_path / "run"))
    # some chunks were merged from the spill manifest, not recomputed
    assert any(
        r.logs["streaming"]["n_resumed_chunks"] > 0
        for r in res.results.values()
    )

    for metric in ("exact_match", "token_f1"):
        c_ref = ref.comparison("stream", metric, *ref.models)
        c_res = res.comparison("stream", metric, *res.models)
        assert c_res.diff == c_ref.diff
        assert c_res.diff_ci == c_ref.diff_ci
        assert c_res.test.p_value == c_ref.test.p_value
        assert c_res.test.statistic == c_ref.test.statistic
        assert c_res.effect.value == c_ref.effect.value
    for key, r in res.results.items():
        for m, mv in r.metrics.items():
            assert mv.value == ref.results[key].metrics[m].value
            assert mv.ci == ref.results[key].metrics[m].ci


# -- satellite regressions -----------------------------------------------------


def test_lexical_normalization_memoized():
    from repro.metrics import lexical

    lexical._normalize_cached.cache_clear()
    lexical._norm_tokens_cached.cache_clear()
    preds = [f"The Answer {i}!" for i in range(50)]
    refs = [f"answer {i}" for i in range(50)]
    out = {}
    for name in ("exact_match", "token_f1", "rouge_l"):
        out[name] = lexical.batch_lexical(name, preds, refs)
    # token_f1 and rouge_l share one tokenization per distinct string
    assert lexical._norm_tokens_cached.cache_info().hits >= 2 * len(preds)
    # memoized results match fresh scalar computation
    assert out["token_f1"][3] == pytest.approx(
        lexical.token_f1("The Answer 3!", "answer 3")
    )
    assert out["exact_match"].mean() == pytest.approx(1.0)
    # oversized strings bypass the cache (no heap pinning) but score the same
    long_pred = "word " * 300  # > _MEMO_MAX_LEN chars
    before = lexical._norm_tokens_cached.cache_info().currsize
    assert lexical.token_f1(long_pred, "word") > 0.0
    assert lexical._norm_tokens_cached.cache_info().currsize <= before + 1


def test_score_stage_caches_metric_resolution(monkeypatch):
    import repro.core.stages as stages_mod
    from repro.core.stages import ScoreStage

    calls = {"n": 0}
    real = stages_mod.resolve_metrics

    def counting(cfgs):
        calls["n"] += 1
        return real(cfgs)

    monkeypatch.setattr(stages_mod, "resolve_metrics", counting)
    stage = ScoreStage()
    task = _task(max_memory_rows=32)

    class _Session:
        judge_engine = None

    from repro.core.stages import EvalArtifact

    for lo in range(0, 128, 32):  # four "chunks" through one stage object
        art = EvalArtifact(
            rows=[{"reference": f"r{i}"} for i in range(lo, lo + 32)],
            task=task,
        )
        art.texts = [f"r{i}" for i in range(lo, lo + 32)]
        stage.run(art, _Session())
    assert calls["n"] == 1
