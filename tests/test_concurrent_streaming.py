"""Concurrent chunk executor (ISSUE 3): golden parity with the serial
pipelines, chunk-level speculation + first-committer-wins manifest dedup,
concurrent-commit stress, crash-resume bit-equality, cache thread safety."""

import threading
import time

import pytest

from repro.core import (
    CacheEntry,
    ConcurrentStreamingExecutor,
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    ResponseCache,
    StatisticsConfig,
)
from repro.core.streaming import _run_key
from repro.data import iter_qa_examples, mixed_examples, qa_examples
from repro.ft import ChunkCrashMiddleware, Fault, FlakyFn, SimulatedCrash
from repro.ft.workers import WorkerPool
from repro.storage.spill import ChunkManifest

M = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")


def _task(
    task_id="conc", ci_method="percentile", cache_dir="", **stream_kw
) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        model=M,
        inference=InferenceConfig(
            batch_size=16, n_workers=3, cache_dir=cache_dir
        ),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method=ci_method
        ),
    ).with_streaming(**stream_kw)


def _mv_tuple(mv):
    return (mv.value, mv.ci, mv.ci_method, mv.n, mv.n_unscored)


# -- golden parity -------------------------------------------------------------


def test_golden_parity_concurrent_vs_serial_bitwise():
    """Concurrent streaming at windows 1, 2 and 8 is byte-identical to
    serial streaming on the mixed QA/summarization/instruction dataset —
    values, CIs, engine-call accounting, chunk counts."""
    rows = mixed_examples(240, seed=21)
    task = _task(max_memory_rows=48)
    with EvalSession() as session:
        serial = session.run_task(iter(rows), task)
    for window in (1, 2, 8):
        with EvalSession() as session:
            ex = ConcurrentStreamingExecutor(chunk_size=48, window=window)
            conc = ex.run(iter(rows), task, session)
        assert set(conc.metrics) == set(serial.metrics)
        for m, mv in serial.metrics.items():
            assert _mv_tuple(conc.metrics[m]) == _mv_tuple(mv), (window, m)
        # engine-call accounting: total demand (paid calls + coalesced
        # waiters) is conserved.  Concurrent windows may pay *fewer* calls
        # than serial when duplicate prompts from different chunks are in
        # flight together — the inference service single-flights them —
        # so calls is upper-bounded by serial, never above it.
        conc_demand = (
            conc.engine_stats["calls"] + conc.engine_stats["coalesced"]
        )
        serial_demand = (
            serial.engine_stats["calls"] + serial.engine_stats["coalesced"]
        )
        assert conc_demand == serial_demand
        assert conc.engine_stats["calls"] <= serial.engine_stats["calls"]
        assert conc.engine_stats["total_cost"] <= serial.engine_stats[
            "total_cost"
        ] * (1 + 1e-9)
        log = conc.logs["streaming"]
        assert log["n_examples"] == 240
        assert log["n_chunks"] == 5
        assert log["max_inflight_chunks"] == window
        assert conc.responses == [] and conc.scores == {}


def test_golden_parity_vs_in_memory_analytical():
    """Window-N streaming vs serial streaming vs the in-memory pipeline on
    the analytical CI path: identical values and intervals (up to float
    re-association in the streamed moments)."""
    rows = mixed_examples(180, seed=22)
    with EvalSession() as session:
        mem = session.run_task(rows, _task(ci_method="analytical", enabled=False))
    with EvalSession() as session:
        serial = session.run_task(
            iter(rows), _task(ci_method="analytical", max_memory_rows=40)
        )
    with EvalSession() as session:
        conc = session.run_task(
            iter(rows),
            _task(ci_method="analytical", max_memory_rows=40, concurrency=4),
        )
    for m, mv in mem.metrics.items():
        for other in (serial, conc):
            ov = other.metrics[m]
            assert ov.ci_method == mv.ci_method
            assert ov.n == mv.n and ov.n_unscored == mv.n_unscored
            assert ov.value == pytest.approx(mv.value, rel=1e-12)
            assert ov.ci[0] == pytest.approx(mv.ci[0], rel=1e-6, abs=1e-9)
            assert ov.ci[1] == pytest.approx(mv.ci[1], rel=1e-6, abs=1e-9)
        # serial vs concurrent streaming: bitwise
        assert _mv_tuple(conc.metrics[m]) == _mv_tuple(serial.metrics[m])


def test_cache_accounting_parity_across_modes(tmp_path):
    """Hit/miss/write accounting is identical for in-memory, serial
    streaming and concurrent streaming — cold pass all misses+writes,
    warm pass all hits, nothing double-counted."""
    rows = qa_examples(120, seed=3)
    modes = {
        "mem": dict(enabled=False),
        "serial": dict(max_memory_rows=30),
        "conc": dict(max_memory_rows=30, concurrency=4),
    }
    observed = {}
    for name, stream_kw in modes.items():
        task = _task(cache_dir=str(tmp_path / f"cache-{name}"), **stream_kw)
        with EvalSession() as session:
            cold = session.run_task(iter(rows), task)
            warm = session.run_task(iter(rows), task)
        observed[name] = [
            {k: r.cache_stats[k] for k in ("hits", "misses", "writes")}
            for r in (cold, warm)
        ]
    for name, (cold, warm) in observed.items():
        assert cold == {"hits": 0, "misses": 120, "writes": 120}, name
        assert warm == {"hits": 120, "misses": 0, "writes": 0}, name


def test_concurrency_knob_excluded_from_resume_key():
    task = _task(max_memory_rows=64)
    assert _run_key(task) == _run_key(task.with_streaming(concurrency=8))
    # but the chunk layout still keys the manifest
    assert _run_key(task) != _run_key(task.with_streaming(max_memory_rows=32))


def test_window_bounds_resident_rows():
    task = _task(max_memory_rows=20, concurrency=3)
    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(200, seed=4), task)
    log = res.logs["streaming"]
    assert log["n_examples"] == 200
    # peak materialized examples <= window x chunk (the O(window x chunk)
    # guarantee; reorder-buffered chunks have already been dematerialized)
    assert log["max_resident_rows"] <= 3 * 20


# -- spill: concurrent commits, speculation, crash-resume ----------------------


@pytest.mark.stress
def test_manifest_concurrent_commit_stress(tmp_path):
    """N threads racing try_record over interleaved chunk ids: every chunk
    ends up committed exactly once — no lost commits, no duplicate rows."""
    man = ChunkManifest(str(tmp_path / "spill"), "stress-run")
    n_threads, n_chunks = 6, 30
    barrier = threading.Barrier(n_threads)
    wins = [0] * n_threads
    errors = []

    def worker(t: int) -> None:
        barrier.wait()
        try:
            # each thread walks the chunks from a different offset so every
            # chunk id sees concurrent committers
            for k in range(n_chunks):
                ci = (k + t * 5) % n_chunks
                if man.try_record(ci, {"start": ci, "n_rows": 1, "by": t}):
                    wins[t] += 1
        except Exception as e:  # pragma: no cover — the assertion target
            errors.append(e)

    threads = [
        threading.Thread(target=worker, args=(t,)) for t in range(n_threads)
    ]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert errors == []
    assert sum(wins) == n_chunks  # exactly one winner per chunk
    rows = man.table.read()
    assert len(rows) == n_chunks  # losers left no duplicate rows
    assert sorted(int(r["chunk_id"]) for r in rows) == list(range(n_chunks))
    assert set(man.completed()) == set(range(n_chunks))
    # orphaned loser segments were unlinked, not just unreferenced
    committed_files = {
        f for s in man.table._live_segments() for f in [s["file"]]
    }
    import os

    on_disk = set(os.listdir(os.path.join(man.path, "data")))
    assert on_disk == committed_files


@pytest.mark.stress
def test_speculative_chunk_reissue_first_committer_wins(tmp_path):
    """A straggler chunk is speculatively re-issued; both attempts race the
    manifest commit and exactly one row lands — the merged stream sees one
    result per chunk (no double-counting)."""
    man = ChunkManifest(str(tmp_path / "spill"), "spec-run")
    pool = WorkerPool(
        n_workers=4, straggler_factor=2.0, straggler_min_s=0.05, poll_s=0.005
    )
    attempts: dict[int, int] = {}
    lock = threading.Lock()

    def fn(i: int, item: int, worker: int):
        with lock:
            attempts[i] = attempts.get(i, 0) + 1
            attempt = attempts[i]
        if i == 2 and attempt == 1:
            time.sleep(0.6)  # deterministic straggler: first attempt only
        won = man.try_record(i, {"start": item, "n_rows": 1, "attempt": attempt})
        return (won, attempt)

    results = list(pool.imap_windowed(fn, iter(range(6)), window=4))
    assert sorted(r.index for r in results) == list(range(6))  # one per chunk
    assert pool.stats.speculative_launches >= 1
    assert attempts[2] == 2  # original + speculative twin both ran
    rows = man.table.read()
    assert len(rows) == 6  # first-committer-wins: no duplicate chunk rows
    assert sum(1 for r in results if r.value[0]) == 6  # every yield committed


def test_imap_windowed_retry_and_permanent_failure():
    pool = WorkerPool(n_workers=2, max_retries=2, poll_s=0.001)
    flaky = FlakyFn(lambda i, item, w: item * 2, [Fault(shard=1, attempt=1)])
    results = {
        r.index: r.value
        for r in pool.imap_windowed(flaky, iter([5, 6, 7, 8]), window=2)
    }
    assert results == {0: 10, 1: 12, 2: 14, 3: 16}
    assert pool.stats.retries == 1 and pool.stats.failures == 1
    assert pool.stats.shards == 4

    dead = FlakyFn(
        lambda i, item, w: item,
        [Fault(shard=0, attempt=1), Fault(shard=0, attempt=2)],
    )
    pool2 = WorkerPool(n_workers=2, max_retries=1, poll_s=0.001)
    with pytest.raises(RuntimeError, match="injected failure"):
        list(pool2.imap_windowed(dead, iter([1]), window=2))


def test_imap_windowed_lazy_admission():
    """The source iterator is only advanced when a window slot frees: at
    most ``window`` items are ever materialized."""
    pool = WorkerPool(n_workers=2, poll_s=0.001)
    in_flight = {"now": 0, "max": 0}
    lock = threading.Lock()

    def items():
        for i in range(12):
            with lock:
                in_flight["now"] += 1
                in_flight["max"] = max(in_flight["max"], in_flight["now"])
            yield i

    def fn(i, item, w):
        time.sleep(0.005)
        with lock:
            in_flight["now"] -= 1
        return item

    out = list(pool.imap_windowed(fn, items(), window=3))
    assert len(out) == 12
    assert in_flight["max"] <= 3


def test_concurrent_crash_resume_bit_identical(tmp_path):
    """Kill a concurrent run mid-stream; in-flight chunks drain their
    commits, the restart skips all committed chunks, and the final metrics
    are bit-identical to an uninterrupted run — serial or concurrent."""
    n, chunk = 300, 50
    task = _task(
        max_memory_rows=chunk, concurrency=2,
        spill_dir=str(tmp_path / "spill"),
    )
    ref_task = _task(
        max_memory_rows=chunk, concurrency=2, spill_dir=str(tmp_path / "ref")
    )
    serial_task = _task(
        max_memory_rows=chunk, spill_dir=str(tmp_path / "serial")
    )
    with EvalSession() as session:
        ref = session.run_task(iter_qa_examples(n, seed=8), ref_task)
    with EvalSession() as session:
        serial = session.run_task(iter_qa_examples(n, seed=8), serial_task)

    crash = ChunkCrashMiddleware([Fault(shard=2, attempt=1)])
    with EvalSession(middleware=[crash]) as session:
        with pytest.raises(SimulatedCrash):
            session.run_task(iter_qa_examples(n, seed=8), task)
        calls_first = session.accounting.engine_calls
    assert crash.injected == [(2, 1, "raise")]

    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(n, seed=8), task)
        calls_resumed = session.accounting.engine_calls
    # every chunk was inferred exactly once across both attempts: in-flight
    # chunks at crash time drained their manifest commits and were skipped
    assert calls_first + calls_resumed == n
    log = res.logs["streaming"]
    assert log["n_chunks"] == n // chunk
    assert log["n_resumed_chunks"] >= 3  # >= chunks merged before the crash
    for m, mv in ref.metrics.items():
        assert _mv_tuple(res.metrics[m]) == _mv_tuple(mv)
        assert _mv_tuple(res.metrics[m]) == _mv_tuple(serial.metrics[m])


# -- ResponseCache thread safety -----------------------------------------------


@pytest.mark.stress
def test_response_cache_concurrent_same_key(tmp_path):
    """Regression for the _refresh/write/stat-counter races: many workers
    writing and reading the same prompt_hash concurrently must not lose
    counter increments or corrupt the key set."""
    cache = ResponseCache(str(tmp_path / "cache"))
    entry = CacheEntry(
        prompt_hash="deadbeef", model_name="m", provider="p",
        prompt_text="q", response_text="a", input_tokens=1, output_tokens=1,
        latency_ms=0.0, created_at=time.time(),
    )
    n_threads, n_ops = 6, 10
    barrier = threading.Barrier(n_threads)
    errors = []

    def worker() -> None:
        barrier.wait()
        try:
            for _ in range(n_ops):
                cache.put([entry])
                assert cache.lookup("deadbeef") is not None
        except Exception as e:  # pragma: no cover — the assertion target
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert errors == []
    total = n_threads * n_ops
    assert cache.writes == total          # no lost write increments
    assert cache.hits == total            # every lookup hit, all counted
    assert cache.misses == 0
    stats = cache.stats()
    assert stats["entries"] == 1          # one key, latest-wins on dup rows
    assert stats["hit_rate"] == 1.0
    # a fresh handle sees exactly one logical entry
    fresh = ResponseCache(str(tmp_path / "cache"))
    assert fresh.lookup("deadbeef") is not None
    assert fresh.table.keys() == {"deadbeef"}


# -- suite integration ---------------------------------------------------------


def test_suite_with_streaming_concurrency():
    suite = (
        EvalSuite("conc-suite")
        .add_task(_task("s1"), lambda: iter_qa_examples(120, seed=12))
        .with_streaming(max_memory_rows=30, concurrency=3)
    )
    with EvalSession() as session:
        res = session.run_suite(suite)
    r = res.result("gpt-4o-mini", "s1")
    log = r.logs["streaming"]
    assert log["n_examples"] == 120
    assert log["max_inflight_chunks"] == 3
    assert log["n_chunks"] == 4
