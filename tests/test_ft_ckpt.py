"""Fault tolerance: worker pool retries/speculation, checkpoint integrity,
crash/restart bitwise equivalence, elastic restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ft import Fault, FlakyFn, WorkerPool, simulate_training


def _work(idx, shard, worker):
    return sum(shard)


def test_pool_basic():
    pool = WorkerPool(3)
    res = pool.map_shards(_work, [[1], [2, 3], [4, 5, 6]])
    assert [r.value for r in res] == [1, 5, 15]


def test_pool_retries_injected_failures():
    flaky = FlakyFn(_work, [Fault(shard=1, attempt=1), Fault(shard=1, attempt=2)])
    pool = WorkerPool(2, max_retries=3)
    res = pool.map_shards(flaky, [[1], [2], [3]])
    assert [r.value for r in res] == [1, 2, 3]
    assert pool.stats.retries == 2
    assert res[1].attempts == 3


def test_pool_raises_after_max_retries():
    flaky = FlakyFn(_work, [Fault(shard=0, attempt=a) for a in range(1, 6)])
    pool = WorkerPool(2, max_retries=2)
    with pytest.raises(RuntimeError):
        pool.map_shards(flaky, [[1], [2]])


def test_speculative_reissue_beats_straggler():
    flaky = FlakyFn(_work, [Fault(shard=0, attempt=1, kind="delay", delay_s=0.5)])
    pool = WorkerPool(3, straggler_factor=2.0, straggler_min_s=0.03)
    res = pool.map_shards(flaky, [[9], [1], [2], [3]])
    assert [r.value for r in res] == [9, 1, 2, 3]
    assert pool.stats.speculative_launches >= 1


def test_ckpt_roundtrip_and_checksum(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4, jnp.int32)}}
    cdir = save_checkpoint(str(tmp_path), 5, tree, extra={"note": "x"})
    out, mani = restore_checkpoint(str(tmp_path), template=tree)
    np.testing.assert_array_equal(out["a"], tree["a"])
    assert mani["extra"]["note"] == "x"

    # corrupt a tensor file -> restore must fail checksum verification
    victim = [f for f in os.listdir(cdir) if f.endswith(".npy")][0]
    with open(os.path.join(cdir, victim), "r+b") as f:
        f.seek(-1, 2)
        f.write(b"\xff")
    with pytest.raises(IOError):
        restore_checkpoint(str(tmp_path), template=tree)


def test_ckpt_manager_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=2)
    tree = {"w": jnp.zeros(3)}
    for step in range(1, 9):
        mgr.maybe_save(step, tree)
    steps = sorted(
        int(d.split("-")[1]) for d in os.listdir(tmp_path) if d.startswith("step-")
    )
    assert steps == [6, 8]
    assert latest_step(str(tmp_path)) == 8


def test_crash_restart_bitwise_equivalence(tmp_path):
    def step(state, batch):
        return jax.tree.map(lambda x: x * 1.5 + batch, state)

    init = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    batches = [float(i) for i in range(1, 9)]
    ref = simulate_training(step, init, batches, ckpt_dir=str(tmp_path / "a"))
    crashed = simulate_training(
        step, init, batches, ckpt_dir=str(tmp_path / "b"), crash_at_step=5
    )
    assert crashed is None
    resumed = simulate_training(step, init, batches, ckpt_dir=str(tmp_path / "b"))
    np.testing.assert_array_equal(np.asarray(ref["w"]), np.asarray(resumed["w"]))


def test_elastic_restore_dtype_and_template(tmp_path):
    """Restore casts to the template dtype (e.g. f32 master -> bf16 serve)."""
    tree = {"w": jnp.arange(8.0, dtype=jnp.float32)}
    save_checkpoint(str(tmp_path), 1, tree)
    tmpl = {"w": jax.ShapeDtypeStruct((8,), jnp.bfloat16)}
    out, _ = restore_checkpoint(str(tmp_path), template=tmpl)
    assert out["w"].dtype == jnp.bfloat16

    bad = {"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), template=bad)
