"""Training substrate: optimizer math, schedules, grad-accum equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import params as pm
from repro.models.model import build_model
from repro.train import (
    OptimizerConfig,
    TrainConfig,
    clip_by_global_norm,
    cross_entropy,
    init_opt_state,
    lr_at,
    make_loss_fn,
    make_train_step,
)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 100, 5)]
    assert lrs[0] < lrs[1]  # warmup rising
    assert max(lrs) <= 1e-3 + 1e-9
    assert lrs[-1] < lrs[3]  # cosine decays
    assert lrs[-1] >= 1e-4 - 1e-9  # min_lr_ratio floor


def test_grad_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)
    # below threshold: untouched
    same, _ = clip_by_global_norm(g, 10.0)
    np.testing.assert_array_equal(np.asarray(same["a"]), np.asarray(g["a"]))


def test_cross_entropy_masking_and_vocab_padding():
    logits = jnp.zeros((1, 4, 8), jnp.float32).at[..., 7].set(100.0)
    labels = jnp.asarray([[0, 1, -1, -1]], jnp.int32)
    # vocab_size=6: ids 6,7 are padding and must be masked to -inf
    loss, metrics = cross_entropy(logits, labels, vocab_size=6, z_loss_weight=0.0)
    assert float(metrics["tokens"]) == 2.0
    # padded id 7 had logit 100 but must not dominate: nll = log(6)
    assert float(metrics["nll"]) == pytest.approx(np.log(6), abs=1e-4)


def test_adamw_descends_quadratic():
    w = {"w": jnp.asarray([5.0, -3.0])}
    cfg = OptimizerConfig(
        learning_rate=0.2, warmup_steps=0, total_steps=1000,
        weight_decay=0.0, schedule="constant",
    )
    from repro.train import adamw_update

    state = init_opt_state(w)
    for _ in range(200):
        grads = {"w": 2 * w["w"]}
        w, state, _ = adamw_update(cfg, w, grads, state)
    assert float(jnp.max(jnp.abs(w["w"]))) < 1e-2


def test_grad_accum_equivalence(rng):
    """microbatches=2 must produce (near-)identical grads to one big batch."""
    cfg = ARCHS["qwen3-4b"].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {
        "tokens": toks,
        "labels": jnp.concatenate([toks[:, 1:], -jnp.ones((4, 1), jnp.int32)], 1),
    }

    def grads_with(mb):
        tcfg = TrainConfig(microbatches=mb, compute_dtype=jnp.float32,
                           z_loss_weight=0.0)
        loss_fn = make_loss_fn(model, cfg, tcfg)
        if mb == 1:
            return jax.grad(lambda p: loss_fn(p, batch)[0])(params)
        # run the accumulation path via make_train_step internals
        from repro.train.step import make_train_step

        # reconstruct accumulated grads by calling the private path:
        micro = jax.tree.map(
            lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]), batch
        )
        g = None
        for i in range(mb):
            gi = jax.grad(
                lambda p: loss_fn(p, jax.tree.map(lambda x: x[i], micro))[0]
            )(params)
            g = gi if g is None else jax.tree.map(lambda a, b: a + b, g, gi)
        return jax.tree.map(lambda x: x / mb, g)

    g1 = grads_with(1)
    g2 = grads_with(2)
    # token-weighted vs microbatch-averaged differ only if token counts vary;
    # here every row has the same mask so they must match closely
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g1, g2
    )
    assert max(jax.tree.leaves(diffs)) < 1e-4


def test_train_step_determinism(rng):
    cfg = ARCHS["mamba2-2.7b"].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    tcfg = TrainConfig(compute_dtype=jnp.float32)
    step = jax.jit(make_train_step(model, cfg, tcfg))
    o = init_opt_state(params)
    p1, _, m1 = step(params, o, batch)
    p2, _, m2 = step(params, o, batch)
    assert float(m1["loss"]) == float(m2["loss"])
    l1 = jax.tree.leaves(p1)
    l2 = jax.tree.leaves(p2)
    assert all(np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(l1, l2))
