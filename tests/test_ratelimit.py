"""Token-bucket rate limiter (Algorithm 1) with an injected clock."""

import time

import pytest

from repro.core.ratelimit import AdaptiveLimiter, TokenBucket


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


def test_bucket_allows_burst_then_throttles():
    clock = FakeClock()
    tb = TokenBucket(60, 6000, 1, clock=clock, sleep=clock.sleep)
    # initial budget: 60 requests
    for _ in range(60):
        w = tb.acquire(10)
        assert w == 0.0
    w = tb.acquire(10)  # 61st must wait ~1s (refill rate 1 req/s)
    assert w == pytest.approx(1.0, abs=0.01)


def test_token_limit_binds():
    clock = FakeClock()
    tb = TokenBucket(1e9, 600, 1, clock=clock, sleep=clock.sleep)  # 10 tok/s
    assert tb.acquire(600) == 0.0          # drains the token bucket
    w = tb.acquire(100)                     # needs 100 tokens -> 10s refill
    assert w == pytest.approx(10.0, abs=0.01)


def test_per_worker_split():
    clock = FakeClock()
    tb = TokenBucket(100, 10_000, n_workers=4, clock=clock, sleep=clock.sleep)
    assert tb.r == 25.0 and tb.t == 2500.0


def test_refill_caps_at_limit():
    clock = FakeClock()
    tb = TokenBucket(60, 6000, 1, clock=clock, sleep=clock.sleep)
    tb.acquire(1)
    clock.t += 3600.0  # one hour idle
    tb._refill()
    assert tb.request_tokens <= 60.0


def test_adaptive_rebalances_to_demand():
    clock = FakeClock()
    lim = AdaptiveLimiter(
        100, 1e6, n_workers=4, window=1.0, floor=0.2,
        clock=clock, sleep=clock.sleep,
    )
    # worker 0 is hot, workers 1-3 idle
    for _ in range(30):
        lim.acquire(0, 10)
        clock.t += 0.05
    clock.t += 2.0
    lim._maybe_rebalance()
    rates = [b.r for b in lim.buckets]
    assert rates[0] > rates[1] == rates[2] == rates[3]
    assert rates[0] > 100 / 4  # hot worker got more than the even split
    assert min(rates) >= 100 * 0.2 / 4 - 1e-9  # floor respected
    assert sum(rates) == pytest.approx(100.0)


def test_wait_accounting():
    clock = FakeClock()
    tb = TokenBucket(60, 1e9, 1, clock=clock, sleep=clock.sleep)
    for _ in range(61):
        tb.acquire(0)
    assert tb.total_wait > 0.9
    assert tb.acquires == 61


# -- contention / invariant coverage (ISSUE 5 satellite) ------------------------


def test_bucket_acquire_refill_math_under_contention():
    """N threads hammering one bucket: no increment is lost and the
    budget math balances exactly (no refill elapses on the fake clock,
    so final budget == initial - consumed)."""
    import threading

    clock = FakeClock()
    tb = TokenBucket(1e6, 1e8, 1, clock=clock, sleep=clock.sleep)
    n_threads, per_thread, tok = 8, 25, 5.0

    def worker():
        for _ in range(per_thread):
            tb.acquire(tok)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = n_threads * per_thread
    assert tb.acquires == total
    assert tb.request_tokens == pytest.approx(tb.r - total)
    assert tb.token_tokens == pytest.approx(tb.t - total * tok)
    assert tb.total_wait == 0.0


def test_bucket_contended_waits_never_overdraw():
    """When the budget forces waits, the post-sleep refill must leave the
    bucket non-negative and the wait accounting consistent."""
    import threading

    clock = FakeClock()
    tb = TokenBucket(60, 1e9, 1, clock=clock, sleep=clock.sleep)
    n_threads, per_thread = 4, 20

    def worker():
        for _ in range(per_thread):
            w = tb.acquire(0.0)
            assert w >= 0.0

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tb.acquires == n_threads * per_thread
    # 80 requests against a 60-burst bucket: 20 must have waited ~1s each
    assert tb.total_wait == pytest.approx(20.0, rel=0.05)
    assert tb.request_tokens >= -1e-9


def test_adaptive_rebalance_share_sum_invariants():
    """After any rebalance: shares are a convex combination (sum == 1),
    every worker keeps at least the floor, and RPM/TPM grants sum to the
    global limits."""
    clock = FakeClock()
    # limits high enough that no acquire sleeps: the fake clock stays
    # pinned inside the window until the explicit rebalance below
    lim = AdaptiveLimiter(
        1e6, 1e9, n_workers=4, window=1.0, floor=0.25,
        clock=clock, sleep=clock.sleep,
    )
    assert sum(lim.shares()) == pytest.approx(1.0)
    # skew demand: worker 0 hot, worker 1 warm, 2-3 idle
    for i in range(40):
        lim.acquire(0, 10)
        if i % 4 == 0:
            lim.acquire(1, 10)
    clock.t += 2.0
    lim._maybe_rebalance()
    shares = lim.shares()
    assert sum(shares) == pytest.approx(1.0)
    assert sum(b.r for b in lim.buckets) == pytest.approx(lim.rpm)
    assert sum(b.t for b in lim.buckets) == pytest.approx(lim.tpm)
    assert min(shares) >= 0.25 / 4 - 1e-9
    assert shares[0] > shares[1] > shares[2] == shares[3]
    # a zero-demand window leaves the assignment untouched
    before = [b.r for b in lim.buckets]
    clock.t += 2.0
    lim._maybe_rebalance()
    assert [b.r for b in lim.buckets] == before
    # within-window calls never rebalance
    lim.acquire(2, 1)
    assert [b.r for b in lim.buckets] == before


def test_adaptive_rebalance_under_contention_preserves_sums():
    """Rebalances racing concurrent acquires (the service-dispatcher
    pattern) must keep the share-sum invariant and lose no acquires."""
    import threading

    lim = AdaptiveLimiter(
        1e9, 1e12, n_workers=4, window=0.0005, floor=0.2,
        sleep=lambda s: None,
    )
    per_thread = 300

    def worker(w):
        for _ in range(per_thread):
            lim.acquire(w, 3.0)

    threads = [threading.Thread(target=worker, args=(w,)) for w in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sum(b.acquires for b in lim.buckets) == 4 * per_thread
    # a rebalance racing a held bucket lock may skip that bucket for one
    # window; an uncontended rebalance restores the exact invariants
    for w in range(4):
        lim.acquire(w, 1.0)
    time.sleep(0.002)
    lim._maybe_rebalance()
    assert sum(lim.shares()) == pytest.approx(1.0)
    assert sum(b.r for b in lim.buckets) == pytest.approx(lim.rpm)
    assert sum(b.t for b in lim.buckets) == pytest.approx(lim.tpm)
    assert min(lim.shares()) >= 0.2 / 4 - 1e-9
