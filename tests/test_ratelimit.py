"""Token-bucket rate limiter (Algorithm 1) with an injected clock."""

import pytest

from repro.core.ratelimit import AdaptiveLimiter, TokenBucket


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, s):
        self.t += s


def test_bucket_allows_burst_then_throttles():
    clock = FakeClock()
    tb = TokenBucket(60, 6000, 1, clock=clock, sleep=clock.sleep)
    # initial budget: 60 requests
    for _ in range(60):
        w = tb.acquire(10)
        assert w == 0.0
    w = tb.acquire(10)  # 61st must wait ~1s (refill rate 1 req/s)
    assert w == pytest.approx(1.0, abs=0.01)


def test_token_limit_binds():
    clock = FakeClock()
    tb = TokenBucket(1e9, 600, 1, clock=clock, sleep=clock.sleep)  # 10 tok/s
    assert tb.acquire(600) == 0.0          # drains the token bucket
    w = tb.acquire(100)                     # needs 100 tokens -> 10s refill
    assert w == pytest.approx(10.0, abs=0.01)


def test_per_worker_split():
    clock = FakeClock()
    tb = TokenBucket(100, 10_000, n_workers=4, clock=clock, sleep=clock.sleep)
    assert tb.r == 25.0 and tb.t == 2500.0


def test_refill_caps_at_limit():
    clock = FakeClock()
    tb = TokenBucket(60, 6000, 1, clock=clock, sleep=clock.sleep)
    tb.acquire(1)
    clock.t += 3600.0  # one hour idle
    tb._refill()
    assert tb.request_tokens <= 60.0


def test_adaptive_rebalances_to_demand():
    clock = FakeClock()
    lim = AdaptiveLimiter(
        100, 1e6, n_workers=4, window=1.0, floor=0.2,
        clock=clock, sleep=clock.sleep,
    )
    # worker 0 is hot, workers 1-3 idle
    for _ in range(30):
        lim.acquire(0, 10)
        clock.t += 0.05
    clock.t += 2.0
    lim._maybe_rebalance()
    rates = [b.r for b in lim.buckets]
    assert rates[0] > rates[1] == rates[2] == rates[3]
    assert rates[0] > 100 / 4  # hot worker got more than the even split
    assert min(rates) >= 100 * 0.2 / 4 - 1e-9  # floor respected
    assert sum(rates) == pytest.approx(100.0)


def test_wait_accounting():
    clock = FakeClock()
    tb = TokenBucket(60, 1e9, 1, clock=clock, sleep=clock.sleep)
    for _ in range(61):
        tb.acquire(0)
    assert tb.total_wait > 0.9
    assert tb.acquires == 61
