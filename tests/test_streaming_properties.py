"""Property-based tests for the streaming merge laws (ISSUE 3).

The concurrent chunk executor is only correct if the streaming
accumulators obey their algebra: folding per-chunk partial states under
*any* partition of the dataset and *any* merge order must reproduce the
single-pass result, and the derived CIs must be invariant to merge order.
Each law lives in a plain ``check_*`` helper so the same assertions run
two ways: hypothesis drives them over arbitrary inputs (skipped cleanly
when hypothesis is not installed, via ``tests/_hypothesis_compat``), and
seeded deterministic tests drive them on every interpreter.

Merges are additive folds of floats, so "equal" means equal to within
float summation re-association (tolerance 1e-9 on sums of [0, 1] scores);
integer state (n, n_nan) must match exactly.  The executor itself gets
*bit*-identical output by merging in chunk-index order — proven in
``tests/test_concurrent_streaming.py`` — while these laws establish that
any order is statistically the same state.
"""

import math

import numpy as np
import pytest
from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

from repro.stats import MetricAccumulator, PoissonBootstrap, streaming_ci

# -- law checkers (shared by hypothesis and seeded tests) ----------------------


def _split(scores: np.ndarray, sizes: list[int]):
    """Partition ``scores`` into consecutive chunks of the given sizes;
    returns [(start_offset, chunk_array), ...] covering the whole array."""
    parts = []
    lo = 0
    for size in sizes:
        parts.append((lo, scores[lo:lo + size]))
        lo += size
    assert lo == len(scores)
    return parts


def check_accumulator_partition_law(
    scores: np.ndarray, sizes: list[int], order: list[int]
) -> None:
    """Merging per-chunk MetricAccumulators in any order == one full pass."""
    full = MetricAccumulator()
    full.update(scores)
    parts = _split(scores, sizes)
    merged = MetricAccumulator()
    for j in order:
        part = MetricAccumulator()
        part.update(parts[j][1])
        # round-trip through the spill serialization on every merge
        merged.merge(MetricAccumulator.from_state(part.state()))
    assert merged.n == full.n
    assert merged.n_nan == full.n_nan
    assert merged.total == pytest.approx(full.total, rel=1e-9, abs=1e-9)
    assert merged.total_sq == pytest.approx(full.total_sq, rel=1e-9, abs=1e-9)
    if full.n:
        assert merged.mean == pytest.approx(full.mean, rel=1e-9, abs=1e-9)
        assert merged.variance == pytest.approx(
            full.variance, rel=1e-6, abs=1e-9
        )


def check_bootstrap_partition_law(
    scores: np.ndarray, sizes: list[int], order: list[int],
    n_boot: int = 50, seed: int = 3,
) -> None:
    """For a fixed chunk layout, merging per-chunk PoissonBootstraps in any
    order == sequentially updating one instance: the Philox streams are
    keyed by (seed, chunk offset), not by processing order."""
    parts = _split(scores, sizes)
    seq = PoissonBootstrap(n_boot, seed)
    for start, part in parts:
        seq.update(part, start)
    merged = PoissonBootstrap(n_boot, seed)
    for j in order:
        start, part = parts[j]
        p = PoissonBootstrap(n_boot, seed)
        p.update(part, start)
        merged.merge(PoissonBootstrap.from_state(p.state()))
    np.testing.assert_allclose(merged.sum_wx, seq.sum_wx, rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(merged.sum_w, seq.sum_w, rtol=1e-9, atol=1e-9)


def check_ci_merge_order_invariance(
    scores: np.ndarray, sizes: list[int], order_a: list[int],
    order_b: list[int], method: str,
) -> None:
    """streaming_ci over states merged in two different orders agrees."""
    parts = _split(scores, sizes)

    def fold(order):
        acc = MetricAccumulator()
        boot = PoissonBootstrap(50, 3) if method != "analytical" else None
        for j in order:
            start, part = parts[j]
            a = MetricAccumulator()
            a.update(part)
            acc.merge(a)
            if boot is not None:
                b = PoissonBootstrap(50, 3)
                b.update(part, start)
                boot.merge(b)
        return acc, boot

    acc_a, boot_a = fold(order_a)
    acc_b, boot_b = fold(order_b)
    if acc_a.n == 0:
        assert acc_b.n == 0
        return
    iv_a = streaming_ci(acc_a, boot_a, method=method)
    iv_b = streaming_ci(acc_b, boot_b, method=method)
    for x, y in [(iv_a.value, iv_b.value), (iv_a.lo, iv_b.lo),
                 (iv_a.hi, iv_b.hi)]:
        if math.isnan(x):
            assert math.isnan(y)
        else:
            assert x == pytest.approx(y, rel=1e-9, abs=1e-9)
    assert iv_a.n == iv_b.n


def _random_case(rng: np.random.Generator, n_max: int = 200):
    """One random (scores, sizes, order) instance for the seeded tests."""
    n = int(rng.integers(1, n_max))
    scores = rng.random(n)
    scores[rng.random(n) < 0.1] = np.nan
    sizes = []
    left = n
    while left > 0:
        take = int(rng.integers(1, left + 1))
        sizes.append(take)
        left -= take
    order = list(rng.permutation(len(sizes)))
    return scores, sizes, order


# -- hypothesis-driven ---------------------------------------------------------

_SCORE = st.one_of(
    st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
    st.just(float("nan")),
)
_PARTS = st.lists(st.lists(_SCORE, min_size=0, max_size=40),
                  min_size=1, max_size=6)


def _materialize(parts: list[list[float]], perm_seed: int):
    scores = np.asarray([x for p in parts for x in p], np.float64)
    sizes = [len(p) for p in parts]
    order = list(np.random.default_rng(perm_seed).permutation(len(parts)))
    return scores, sizes, order


@settings(max_examples=40, deadline=None)
@given(parts=_PARTS, perm_seed=st.integers(0, 2**31 - 1))
def test_prop_accumulator_merge_law(parts, perm_seed):
    scores, sizes, order = _materialize(parts, perm_seed)
    check_accumulator_partition_law(scores, sizes, order)


@settings(max_examples=25, deadline=None)
@given(parts=_PARTS, perm_seed=st.integers(0, 2**31 - 1))
def test_prop_bootstrap_merge_law(parts, perm_seed):
    scores, sizes, order = _materialize(parts, perm_seed)
    check_bootstrap_partition_law(scores, sizes, order)


@settings(max_examples=25, deadline=None)
@given(
    parts=_PARTS,
    perm_seed=st.integers(0, 2**31 - 1),
    perm_seed_b=st.integers(0, 2**31 - 1),
    method=st.sampled_from(["analytical", "percentile"]),
)
def test_prop_streaming_ci_merge_order_invariant(
    parts, perm_seed, perm_seed_b, method
):
    scores, sizes, order_a = _materialize(parts, perm_seed)
    order_b = list(
        np.random.default_rng(perm_seed_b).permutation(len(sizes))
    )
    check_ci_merge_order_invariance(scores, sizes, order_a, order_b, method)


# -- seeded deterministic coverage (runs without hypothesis) -------------------


def test_seeded_accumulator_merge_law():
    rng = np.random.default_rng(11)
    for _ in range(20):
        check_accumulator_partition_law(*_random_case(rng))


def test_seeded_bootstrap_merge_law():
    rng = np.random.default_rng(12)
    for _ in range(10):
        check_bootstrap_partition_law(*_random_case(rng))


def test_seeded_ci_merge_order_invariance():
    rng = np.random.default_rng(13)
    for method in ("analytical", "percentile"):
        for _ in range(5):
            scores, sizes, order_a = _random_case(rng)
            order_b = list(rng.permutation(len(sizes)))
            check_ci_merge_order_invariance(
                scores, sizes, order_a, order_b, method
            )


def test_hypothesis_shim_reports_mode():
    # documents which mode this run exercised (skip-shim vs real driver)
    assert HAVE_HYPOTHESIS in (True, False)
