"""Collective-bytes parser on synthetic and real compiled HLO."""

import jax
import pytest

from repro.launch.hlo_analysis import collective_stats

SYNTHETIC = """
  %ag = bf16[8,1024]{1,0} all-gather(bf16[8,256]{1,0} %x), replica_groups=[2,4]<=[8], dimensions={1}
  %ar = f32[128]{0} all-reduce(f32[128]{0} %y), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %rs = f32[64]{0} reduce-scatter(f32[256]{0} %z), replica_groups=[2,4]<=[8], dimensions={0}
  %cp = s32[16]{0} collective-permute(s32[16]{0} %w), source_target_pairs={{0,1}}
  %a2a = bf16[32,32]{1,0} all-to-all(bf16[32,32]{1,0} %v), replica_groups=[1,8]<=[8]
"""


def test_synthetic_parse():
    st = collective_stats(SYNTHETIC, 8)
    ops = st.by_op
    assert set(ops) == {
        "all-gather", "all-reduce", "reduce-scatter", "collective-permute",
        "all-to-all",
    }
    # all-gather: out 8*1024*2 bytes * (4-1)/4
    assert ops["all-gather"][1] == pytest.approx(8 * 1024 * 2 * 3 / 4)
    # all-reduce: 2 * 128*4 * (4-1)/4  (explicit groups of size 4)
    assert ops["all-reduce"][1] == pytest.approx(2 * 512 * 3 / 4)
    # reduce-scatter: out 64*4 * (4-1)
    assert ops["reduce-scatter"][1] == pytest.approx(256 * 3)
    # permute: raw bytes
    assert ops["collective-permute"][1] == pytest.approx(16 * 4)
    # all-to-all: 32*32*2 * 7/8
    assert ops["all-to-all"][1] == pytest.approx(2048 * 7 / 8)


def test_group_size_one_skipped():
    st = collective_stats(
        "%ar = f32[4]{0} all-reduce(f32[4]{0} %x), replica_groups=[8,1]<=[8]", 8
    )
    assert st.wire_bytes == 0.0


def test_real_compiled_module_has_collectives():
    """Shard a matmul over fake devices in a subprocess-free way: reuse the
    current process only if it already has >1 device; otherwise skip (tests
    must not set XLA_FLAGS)."""
    if len(jax.devices()) < 2:
        pytest.skip("single-device process (by design for the test suite)")
