"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes and absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import params as pm
from repro.models.model import build_model
from repro.train import OptimizerConfig, TrainConfig, init_opt_state, make_train_step

B, S = 2, 32


def _batch(cfg, rng):
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model) * 0.02, jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.n_vision_tokens, cfg.d_model) * 0.02, jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_smoke(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    batch = _batch(cfg, rng)
    logits, aux = model.forward(params, batch, dtype=jnp.float32)
    extra = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + extra, cfg.padded_vocab)
    assert not np.any(np.isnan(np.asarray(logits)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch, rng):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    batch = _batch(cfg, rng)
    toks = batch["tokens"]
    batch["labels"] = jnp.concatenate(
        [toks[:, 1:], -jnp.ones((B, 1), jnp.int32)], axis=1
    )
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(warmup_steps=1, total_steps=4),
        compute_dtype=jnp.float32,
    )
    step = jax.jit(make_train_step(model, cfg, tcfg))
    opt = init_opt_state(params)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(opt2.step) == 1
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, t: acc or bool(np.any(np.asarray(t[0]) != np.asarray(t[1]))),
        jax.tree.map(lambda a, b: (a, b), params, params2),
        False,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    assert moved


def test_memorization_loss_decreases(rng):
    cfg = ARCHS["qwen3-4b"].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (4, S)), jnp.int32)
    batch = {
        "tokens": toks,
        "labels": jnp.concatenate([toks[:, 1:], -jnp.ones((4, 1), jnp.int32)], 1),
    }
    tcfg = TrainConfig(
        optimizer=OptimizerConfig(warmup_steps=2, total_steps=12),
        microbatches=2,
        compute_dtype=jnp.float32,
    )
    step = jax.jit(make_train_step(model, cfg, tcfg))
    opt = init_opt_state(params)
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
