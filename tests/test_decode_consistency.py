"""Prefill + incremental decode must reproduce full-forward logits.

This is the strongest correctness property of the serving stack: KV/SSM
cache contents, position handling, masked cache updates and the absorbed
MLA formulation all have to be exactly right for it to hold.  MoE archs are
tested dropless (capacity semantics legitimately differ between solo-token
routing and full-sequence routing; see models/moe.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import params as pm
from repro.models.model import build_model

B, S, MAX = 2, 16, 24


def _setup(arch, rng):
    cfg = ARCHS[arch].reduced()
    if cfg.n_experts:
        cfg = cfg.replace(
            capacity_factor=float(cfg.n_experts) / cfg.n_experts_per_token
        )
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    prefix = 0
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model) * 0.02, jnp.float32
        )
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.n_vision_tokens, cfg.d_model) * 0.02, jnp.float32
        )
        prefix = cfg.n_vision_tokens
    return cfg, model, params, batch, toks, prefix


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_matches_forward(arch, rng):
    cfg, model, params, batch, toks, prefix = _setup(arch, rng)
    logits_full, _ = model.forward(params, batch, dtype=jnp.float32)

    split = S - 4
    cache = pm.init_params(
        jax.random.key(1), model.cache_specs(B, MAX + prefix, jnp.float32)
    )
    pb = dict(batch)
    pb["tokens"] = toks[:, :split]
    lg, cache = model.prefill(params, pb, cache, dtype=jnp.float32)
    errs = [
        float(np.max(np.abs(
            np.asarray(lg) - np.asarray(logits_full[:, prefix + split - 1])
        )))
    ]
    for t in range(split, S):
        pos = jnp.full((B,), prefix + t, jnp.int32)
        lg, cache = model.decode_step(
            params, toks[:, t : t + 1], cache, pos, dtype=jnp.float32
        )
        errs.append(
            float(np.max(np.abs(
                np.asarray(lg) - np.asarray(logits_full[:, prefix + t])
            )))
        )
    assert max(errs) < 5e-5, f"{arch}: max err {max(errs):.2e}"


def test_ragged_positions_decode(rng):
    """Decode with different positions per row (continuous batching) matches
    row-by-row decoding."""
    cfg = ARCHS["qwen3-4b"].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    lens = [6, 11]
    toks = [rng.randint(3, cfg.vocab_size, (n,)).tolist() for n in lens]

    # batched: prefill each row alone, insert into a 2-slot cache via the
    # scheduler machinery; here simulate by separate caches and compare the
    # decode logits at ragged positions vs single-row runs.
    outs = []
    for row in toks:
        cache = pm.init_params(
            jax.random.key(1), model.cache_specs(1, MAX, jnp.float32)
        )
        arr = jnp.asarray([row], jnp.int32)
        lg, cache = model.prefill(params, {"tokens": arr}, cache, dtype=jnp.float32)
        nxt = jnp.asarray([[int(np.argmax(np.asarray(lg)[0]))]], jnp.int32)
        lg2, _ = model.decode_step(
            params, nxt, cache, jnp.asarray([len(row)], jnp.int32), dtype=jnp.float32
        )
        outs.append(np.asarray(lg2)[0])
    assert all(np.all(np.isfinite(o)) for o in outs)
