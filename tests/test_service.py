"""Shared asynchronous inference service (ISSUE 5): single-flight
coalescing, duplicate-spend regression, golden parity of every execution
mode vs the lock-step baseline, batcher-loop dispatch, drain/shutdown,
retry accounting, parallel suite jobs, serving counters in reports."""

import dataclasses
import threading

import pytest

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    InferenceRequest,
    InferenceService,
    MetricConfig,
    SimulatedAPIEngine,
    StatisticsConfig,
)
from repro.data import mixed_examples, qa_examples

API_MODEL = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")
SLOT_MODEL = EngineModelConfig(provider="slotsim", model_name="slot-sim")


def _task(
    task_id="svc",
    model=API_MODEL,
    cache_dir="",
    use_service=True,
    n_workers=4,
    **inf_kw,
):
    return EvalTask(
        task_id=task_id,
        model=model,
        inference=InferenceConfig(
            batch_size=8, n_workers=n_workers, cache_dir=cache_dir,
            use_service=use_service, **inf_kw,
        ),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    )


def _mv_tuple(mv):
    return (mv.value, mv.ci, mv.ci_method, mv.n, mv.n_unscored)


def _cmp_tuple(c):
    return (c.diff, c.diff_ci, c.test.p_value, c.effect.value)


class GatedEngine(SimulatedAPIEngine):
    """Engine whose calls block on an event — makes in-flight overlap
    deterministic for single-flight tests."""

    def __init__(self, model, gate, **kw):
        super().__init__(model, **kw)
        self.gate = gate

    def infer(self, request):
        assert self.gate.wait(10.0), "test gate never opened"
        return super().infer(request)


# -- single flight -------------------------------------------------------------


def test_single_flight_one_engine_call_n_waiters():
    gate = threading.Event()
    eng = GatedEngine(API_MODEL, gate)
    eng.initialize()
    svc = InferenceService(eng, n_dispatchers=4, name="gated")
    req = InferenceRequest("what is the capital of France", 16, 0.0)
    tickets = [svc.submit(req, key="k1") for _ in range(5)]
    assert tickets[0].primary
    assert not any(t.primary for t in tickets[1:])
    gate.set()
    texts = {t.result(timeout=10.0).text for t in tickets}
    assert len(texts) == 1
    assert eng.calls == 1  # one engine call, five waiters
    snap = svc.snapshot()
    assert snap["submitted"] == 5 and snap["coalesced"] == 4
    assert snap["dispatched"] == 1
    assert snap["dedup_rate"] == pytest.approx(0.8)
    svc.close()


def test_coalesce_disabled_pays_per_submission():
    gate = threading.Event()
    eng = GatedEngine(API_MODEL, gate)
    eng.initialize()
    svc = InferenceService(eng, n_dispatchers=4, coalesce=False)
    req = InferenceRequest("same prompt twice", 16, 0.0)
    t1 = svc.submit(req, key="k")
    t2 = svc.submit(req, key="k")
    assert t1.primary and t2.primary
    gate.set()
    t1.result(timeout=10.0), t2.result(timeout=10.0)
    assert eng.calls == 2
    svc.close()


def test_completed_flight_does_not_coalesce():
    eng = SimulatedAPIEngine(API_MODEL)
    eng.initialize()
    svc = InferenceService(eng, n_dispatchers=2)
    req = InferenceRequest("one then later the same", 16, 0.0)
    t1 = svc.submit(req, key="k")
    t1.result(timeout=10.0)
    t2 = svc.submit(req, key="k")  # flight finished: new engine call
    t2.result(timeout=10.0)
    assert t2.primary and eng.calls == 2
    svc.close()


# -- the duplicate-spend regression (satellite #1) ------------------------------


def test_duplicate_spend_race_two_chunk_workers(tmp_path):
    """Two concurrent chunk workers missing the cache on the same prompts
    must result in exactly one engine call and one cost increment per
    unique prompt.  The lock-step path (main's behaviour) pays twice."""
    rows = qa_examples(8, seed=3)
    source = rows + rows  # chunk 0 and chunk 1 are identical prompt sets
    kw = {"wall_clock": True, "base_latency_ms": 60.0, "per_token_ms": 0.0}

    def run(use_service):
        task = _task(use_service=use_service).with_streaming(
            max_memory_rows=8, max_inflight_chunks=2
        )
        with EvalSession(engine_kwargs=kw) as session:
            res = session.run_task(iter(source), task)
            acct = dataclasses.asdict(session.accounting)
        return res, acct

    svc_res, svc_acct = run(True)
    lock_res, lock_acct = run(False)

    # lock-step: both chunks pay — the paper's duplicate-spend leak
    assert lock_acct["engine_calls"] == 16
    # service: one flight per unique prompt, the twin chunk coalesces
    assert svc_acct["engine_calls"] == 8
    assert svc_acct["coalesced_requests"] == 8
    assert svc_acct["cost_usd"] == pytest.approx(lock_acct["cost_usd"] / 2)
    # identical evaluation output either way
    for m, mv in lock_res.metrics.items():
        assert _mv_tuple(svc_res.metrics[m]) == _mv_tuple(mv)


# -- golden parity (acceptance) -------------------------------------------------


@pytest.mark.parametrize(
    "stream_kw",
    [
        None,                                           # in-memory
        {"max_memory_rows": 20},                        # serial streaming
        {"max_memory_rows": 20, "concurrency": 4},      # concurrent streaming
    ],
    ids=["memory", "serial-stream", "concurrent-stream"],
)
def test_golden_parity_service_vs_lockstep(stream_kw, tmp_path):
    """In-memory, serial streaming and concurrent streaming through the
    InferenceService produce byte-identical metrics, CIs and comparison
    matrices to the lock-step path."""
    rows = mixed_examples(80, seed=5)

    def build_suite(use_service, tag):
        task = _task(
            task_id="parity", use_service=use_service,
            cache_dir=str(tmp_path / f"cache-{tag}-{use_service}"),
        )
        if stream_kw is not None:
            task = task.with_streaming(**stream_kw)
        src = (lambda: iter(rows)) if stream_kw is not None else rows
        return (
            EvalSuite(f"parity-{use_service}")
            .add_task(task, src)
            .sweep_models([
                API_MODEL,
                EngineModelConfig(provider="anthropic",
                                  model_name="claude-3-haiku"),
            ])
        )

    with EvalSession() as session:
        lock = session.run_suite(build_suite(False, "a"))
    with EvalSession() as session:
        svc = session.run_suite(build_suite(True, "b"))

    for key, lock_res in lock.results.items():
        svc_res = svc.results[key]
        assert set(svc_res.metrics) == set(lock_res.metrics)
        for m, mv in lock_res.metrics.items():
            assert _mv_tuple(svc_res.metrics[m]) == _mv_tuple(mv), (key, m)
    assert set(svc.comparisons) == set(lock.comparisons)
    for task_id, metrics in lock.comparisons.items():
        assert set(svc.comparisons[task_id]) == set(metrics)
        for metric, cells in metrics.items():
            for pair, cmp in cells.items():
                assert _cmp_tuple(svc.comparisons[task_id][metric][pair]) == (
                    _cmp_tuple(cmp)
                ), (task_id, metric, pair)


def test_slot_engine_service_vs_lockstep_parity():
    """The batcher-loop dispatch (continuous batching) returns the same
    responses as lock-step gang decode on the simulated slot engine."""
    rows = mixed_examples(40, seed=7)
    kw = {"n_slots": 4, "step_ms": 0.0}
    with EvalSession(engine_kwargs=kw) as session:
        lock = session.run_task(
            rows, _task(model=SLOT_MODEL, use_service=False)
        )
    with EvalSession(engine_kwargs=kw) as session:
        svc = session.run_task(rows, _task(model=SLOT_MODEL))
        snaps = session.serving_stats()
    assert lock.responses == svc.responses
    for m, mv in lock.metrics.items():
        assert _mv_tuple(svc.metrics[m]) == _mv_tuple(mv)
    (snap,) = snaps
    assert snap["mode"] == "batcher"
    b = snap["batcher"]
    assert b["admissions"] == snap["dispatched"]
    assert b["completions"] == snap["completed"]
    assert 0.0 < b["slot_occupancy"] <= 1.0
    assert 0.0 < b["tokens_per_step"] <= 4.0


# -- dispatch mechanics ---------------------------------------------------------


def test_queue_backpressure_small_depth():
    task = _task(service_queue_depth=2)
    rows = qa_examples(40, seed=11)
    with EvalSession(
        engine_kwargs={"wall_clock": True, "base_latency_ms": 1.0,
                       "per_token_ms": 0.0}
    ) as session:
        res = session.run_task(rows, task)
    assert res.engine_stats["calls"] == 40
    assert not res.failures


def test_retry_accounting_through_service():
    """Recoverable failures retry inside the dispatcher; attempts are
    billed to the owning shard exactly as the lock-step path bills them."""
    rows = qa_examples(9, seed=13)
    task = _task(max_retries=2, retry_delay=0.0)
    with EvalSession(engine_kwargs={"fail_every": 3}) as session:
        res = session.run_task(rows, task)
        acct_calls = session.accounting.engine_calls
    assert not res.failures  # every 429 recovered on retry
    assert res.engine_stats["calls"] > 9  # retries counted as attempts
    assert acct_calls == res.engine_stats["calls"]


def test_unrecoverable_errors_recorded_as_failures():
    rows = qa_examples(6, seed=17)
    task = _task(max_retries=0)
    with EvalSession(engine_kwargs={"fail_every": 3}) as session:
        res = session.run_task(rows, task)
    assert len(res.failures) == 2  # calls 3 and 6 fail, no retries allowed
    assert all(f["error"] == "rate_limited_429" for f in res.failures)


def test_close_drains_inflight_work():
    with EvalSession(
        engine_kwargs={"wall_clock": True, "base_latency_ms": 20.0,
                       "per_token_ms": 0.0}
    ) as session:
        inf = InferenceConfig(n_workers=4)
        svc = session.service_for(API_MODEL, inf)
        tickets = [
            svc.submit(InferenceRequest(f"drain me {i}", 8, 0.0), key=None)
            for i in range(6)
        ]
        session.close()  # must drain queued work, then join dispatchers
        assert all(t.done() for t in tickets)
        assert all(t.result(0.0).error is None for t in tickets)
        with pytest.raises(RuntimeError, match="closed"):
            svc.submit(InferenceRequest("late", 8, 0.0))


def test_attach_scales_dispatchers_and_detach_keeps_them():
    eng = SimulatedAPIEngine(API_MODEL)
    eng.initialize()
    svc = InferenceService(eng, n_dispatchers=2)
    svc.attach(2)
    svc.attach(6)
    assert svc.snapshot()["dispatchers"] == 8
    svc.detach(6)
    svc.detach(2)
    assert svc.snapshot()["dispatchers"] == 8  # threads never shrink
    svc.close()


# -- suite integration ----------------------------------------------------------


def test_parallel_suite_jobs_match_sequential(tmp_path):
    rows = mixed_examples(40, seed=19)
    models = [
        API_MODEL,
        EngineModelConfig(provider="anthropic", model_name="claude-3-haiku"),
    ]

    def build():
        return (
            EvalSuite("par")
            .add_task(_task(task_id="qa"), rows)
            .sweep_models(models)
        )

    with EvalSession() as session:
        seq = session.run_suite(build())
    with EvalSession() as session:
        par = session.run_suite(build(), parallel_jobs=2)
    for key, res in seq.results.items():
        for m, mv in res.metrics.items():
            assert _mv_tuple(par.results[key].metrics[m]) == _mv_tuple(mv)
    for task_id, metrics in seq.comparisons.items():
        for metric, cells in metrics.items():
            for pair, cmp in cells.items():
                assert _cmp_tuple(
                    par.comparisons[task_id][metric][pair]
                ) == _cmp_tuple(cmp)


def test_suite_report_surfaces_serving_counters():
    rows = mixed_examples(20, seed=23)
    suite = EvalSuite("rep").add_task(_task(task_id="qa"), rows)
    with EvalSession() as session:
        res = session.run_suite(suite)
    serving = res.accounting["serving"]
    assert serving and serving[0]["submitted"] == 20
    assert "coalesced_requests" in res.accounting
    md = res.to_markdown()
    assert "## Inference service" in md
    assert "openai:gpt-4o-mini" in md
    assert "dedup" in md
    # accounting line still renders without the nested serving blob
    assert "_session accounting:" in md and "'serving'" not in md


@pytest.mark.stress
def test_service_counter_exactness_under_contention():
    """Many threads hammering one service with overlapping keys: no
    submission is lost, every ticket resolves, exactly one primary per
    flight, and submitted == dispatched + coalesced."""
    eng = SimulatedAPIEngine(API_MODEL)
    eng.initialize()
    svc = InferenceService(eng, n_dispatchers=8, queue_depth=64)
    n_threads, per_thread, n_keys = 12, 50, 25
    results = [[] for _ in range(n_threads)]

    def worker(w):
        for i in range(per_thread):
            k = f"key-{(w * per_thread + i) % n_keys}"
            t = svc.submit(
                InferenceRequest(f"prompt for {k}", 8, 0.0), key=k
            )
            results[w].append((k, t))

    threads = [
        threading.Thread(target=worker, args=(w,)) for w in range(n_threads)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    texts = {}
    for w in range(n_threads):
        for k, t in results[w]:
            resp = t.result(timeout=30.0)
            assert resp.error is None
            texts.setdefault(k, set()).add(resp.text)
    assert all(len(v) == 1 for v in texts.values())  # one text per key
    st = svc.stats
    total = n_threads * per_thread
    assert st.submitted == total
    assert st.dispatched + st.coalesced == total
    assert st.completed == st.dispatched
    assert st.dispatched == eng.calls
    svc.close()


def test_batcher_admission_round_robins_limiter_buckets():
    """The batcher loop must spread admission across the per-worker
    bucket list — pinning worker 0 would cap a slot engine at 1/n of the
    configured budget (regression)."""
    from repro.core import EngineModelConfig, SimulatedSlotEngine, TokenBucket

    eng = SimulatedSlotEngine(SLOT_MODEL, n_slots=4, step_ms=0.0)
    eng.initialize()
    buckets = [TokenBucket(1e9, 1e12, 4, sleep=lambda s: None)
               for _ in range(4)]
    svc = InferenceService(eng, name="slots")
    tickets = [
        svc.submit(
            InferenceRequest(f"spread me {i}", 8, 0.0),
            key=str(i), limiter=buckets, est_tokens=10.0,
        )
        for i in range(12)
    ]
    for t in tickets:
        assert t.result(timeout=30.0).error is None
    assert [b.acquires for b in buckets] == [3, 3, 3, 3]
    svc.close()
