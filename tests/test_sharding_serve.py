"""Sharding rule resolution + serving scheduler behaviour."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.configs import ARCHS
from repro.core.ratelimit import TokenBucket
from repro.models import params as pm
from repro.models.model import build_model
from repro.serve import ContinuousBatcher, Request
from repro.serve.scheduler import batch_axis_tree


# Rule tests use an abstract mesh so they run on the single CPU device.
def _mesh(shape, axes):
    devs = np.array(jax.devices() * int(np.prod(shape)))[: int(np.prod(shape))]
    return jax.sharding.Mesh(devs.reshape(shape), axes)


@pytest.fixture
def rules16():
    mesh = _mesh((4, 4), ("data", "model"))
    return sh.ShardingRules(sh.TRAIN_RULES, mesh)


def test_even_division_shards(rules16):
    spec = rules16.spec_for_axes(("embed", "mlp"), (64, 128))
    assert spec == P("data", "model")


def test_uneven_falls_back_to_replication(rules16):
    # 10 heads on a 4-way model axis: replicate rather than fail
    spec = rules16.spec_for_axes(("batch", None, "heads", None), (8, 9, 10, 64))
    assert spec == P("data")  # trailing Nones trimmed


def test_axis_used_once(rules16):
    # both vocab and mlp want "model": second falls back
    spec = rules16.spec_for_axes(("vocab", "mlp"), (256, 256))
    assert spec == P("model")


def test_pod_fallback_rules():
    m3 = _mesh((2, 2, 2), ("pod", "data", "model"))
    r3 = sh.ShardingRules(sh.TRAIN_RULES, m3)
    assert r3.spec_for_axes(("batch", None), (8, 4)) == P(("pod", "data"))
    # single-pod mesh: same logical name resolves to the fallback rule
    m2 = _mesh((2, 2), ("data", "model"))
    r2 = sh.ShardingRules(sh.TRAIN_RULES, m2)
    assert r2.spec_for_axes(("batch", None), (8, 4)) == P("data")


def test_serve_rules_cache_seq():
    m2 = _mesh((2, 2), ("data", "model"))
    r = sh.ShardingRules(sh.SERVE_RULES, m2)
    axes = ("layers", "batch", "cache_seq", "kv_heads", None)
    spec = r.spec_for_axes(axes, (8, 16, 1024, 8, 64))
    assert spec == P(None, "data", "model")
    # batch=1 long-context: batch drops, seq keeps model
    spec1 = r.spec_for_axes(axes, (8, 1, 1024, 8, 64))
    assert spec1 == P(None, None, "model")


def test_batch_axis_tree():
    cfg = ARCHS["zamba2-7b"].reduced()
    model = build_model(cfg)
    axes = batch_axis_tree(model.cache_specs(4, 32))
    leaves = jax.tree.leaves(axes)
    assert all(isinstance(a, int) for a in leaves)
    # grouped mamba caches have the batch axis at position 2 (G, K, B, ...)
    assert max(leaves) >= 2


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


def _scheduler(n_slots=3, **kw):
    cfg = ARCHS["qwen3-4b"].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    return ContinuousBatcher(
        model, cfg, params, n_slots=n_slots, max_len=64, eos_id=1, **kw
    ), cfg


def test_scheduler_completes_all(rng):
    sched, cfg = _scheduler()
    for i in range(7):
        sched.submit(
            Request(i, prompt_tokens=list(rng.randint(3, 100, 4 + i % 3)),
                    max_new_tokens=5)
        )
    done = sched.run_to_completion()
    assert sorted(c.request_id for c in done) == list(range(7))
    assert all(len(c.tokens) <= 5 for c in done)
    assert all(c.finished_reason in ("eos", "length") for c in done)


def test_scheduler_multiplexes_slots(rng):
    sched, _ = _scheduler(n_slots=2)
    for i in range(5):
        sched.submit(Request(i, prompt_tokens=[5, 6, 7], max_new_tokens=4))
    done = sched.run_to_completion()
    assert len(done) == 5
    # 5 requests x 4 tokens on 2 slots: needs >= 10 decode iterations
    assert sched.steps_run >= 8


def test_scheduler_occupancy_counters(rng):
    """Persistent-mode counters: admissions, occupancy, tokens/step,
    prompt-length recompile tracking, incremental completion draining."""
    sched, _ = _scheduler(n_slots=2)
    for i in range(5):
        # two distinct prompt lengths -> exactly 2 prefill recompiles
        sched.submit(
            Request(i, prompt_tokens=[5, 6, 7][: 2 + i % 2], max_new_tokens=4)
        )
    drained: list = []
    while sched.queue or sched.slots_busy:
        assert 0 <= sched.slots_busy <= 2
        sched.step()
        drained.extend(sched.drain_completions())
    drained.extend(sched.run_to_completion())  # flush terminal slots
    drained.extend(sched.drain_completions())
    seen = {c.request_id for c in drained}
    assert seen == set(range(5))
    st = sched.stats
    assert st.admissions == 5
    assert st.completions >= 5
    assert st.prefill_recompiles == 2
    assert st.steps == sched.steps_run
    assert 0.0 < st.occupancy <= 1.0
    assert 0.0 < st.tokens_per_step <= 2.0
    assert st.tokens_generated == st.active_slot_steps
    d = st.as_dict()
    assert d["n_slots"] == 2 and d["admissions"] == 5
    assert sched.completions == []  # drained incrementally


def test_scheduler_greedy_deterministic(rng):
    s1, _ = _scheduler()
    s2, _ = _scheduler()
    toks = list(rng.randint(3, 90, 6))
    s1.submit(Request(0, prompt_tokens=toks, max_new_tokens=6))
    s2.submit(Request(0, prompt_tokens=toks, max_new_tokens=6))
    d1 = s1.run_to_completion()
    d2 = s2.run_to_completion()
    assert d1[0].tokens == d2[0].tokens


def test_scheduler_paged_parity_and_prefix_sharing(rng):
    """Paged KV cache (16- and 64-token pages, with and without prefix
    sharing) produces byte-identical token streams to the contiguous
    cache; sharing is observable in the stats and leaks nothing."""
    prefix = list(range(10, 28))  # 18 tokens -> one full 16-token page
    reqs = []
    for i in range(6):
        toks = prefix + [100 + i] if i % 2 == 0 else [50 + i, 51 + i, 52 + i]
        reqs.append(Request(i, prompt_tokens=toks, max_new_tokens=6))

    def run(**kw):
        sched, _ = _scheduler(**kw)
        for r in reqs:
            sched.submit(r)
        done = {c.request_id: c for c in sched.run_to_completion()}
        return sched, [done[i].tokens for i in range(6)]

    s0, r0 = run()
    s16, r16 = run(page_size=16)
    s64, r64 = run(page_size=64)
    s16n, r16n = run(page_size=16, prefix_cache=False)
    assert r16 == r0
    assert r64 == r0
    assert r16n == r0
    # requests 2 and 4 each reuse request 0's resident prefix page
    assert s16.stats.prefix_pages_hit == 2
    assert s16.stats.prefix_tokens_saved == 32
    assert s16.stats.cow_copies == 0
    # 64-token pages can't share an 18-token prefix; sharing disabled -> 0
    assert s64.stats.prefix_pages_hit == 0
    assert s16n.stats.prefix_pages_hit == 0
    for s in (s16, s64, s16n):
        s.manager.check_no_leaks()
        assert s.manager.pages_active == 0


def test_scheduler_prefills_deferred_counts_once(rng):
    """Regression (ISSUE 8 S1): a capped refill defers each waiting
    request at most once per step — not once per still-free slot scan.
    4 one-token requests, 2 slots, cap 1: the queue waits behind one
    free slot for 3 rounds -> exactly 3 deferrals (the old accounting
    added len(queue) per round: 3 + 2 + 1 = 6)."""
    sched, _ = _scheduler(n_slots=2, max_prefills_per_step=1)
    for i in range(4):
        sched.submit(Request(i, prompt_tokens=[5, 6, 7], max_new_tokens=1))
    done = sched.run_to_completion()
    assert sorted(c.request_id for c in done) == list(range(4))
    assert sched.stats.admissions == 4
    assert sched.stats.prefills_deferred == 3


def test_scheduler_slot_release_same_step(rng):
    """Regression (ISSUE 8 S2): a slot whose sample just finished is
    reaped *before* refill, so a back-to-back queue keeps one slot at
    100% occupancy with no idle step between requests."""
    sched, _ = _scheduler(n_slots=1)
    for i in range(4):
        sched.submit(Request(i, prompt_tokens=[5, 6, 7], max_new_tokens=3))
    done = sched.run_to_completion()
    assert len(done) == 4
    assert sched.stats.occupancy == 1.0


def test_scheduler_truncated_completion_at_max_steps(rng):
    """Regression (ISSUE 8 S3): exhausting max_steps emits a 'truncated'
    completion for the in-flight request instead of dropping it."""
    sched, _ = _scheduler(n_slots=1)
    sched.submit(Request(0, prompt_tokens=[5, 6, 7], max_new_tokens=50))
    done = sched.run_to_completion(max_steps=3)
    assert len(done) == 1
    assert done[0].finished_reason == "truncated"
    assert 1 <= len(done[0].tokens) <= 4
    assert sched.stats.completions == 1
    assert sched.slots_busy == 0


def test_scheduler_admission_control(rng):
    calls = []
    clockv = [0.0]

    def clock():
        return clockv[0]

    def sleep(s):
        clockv[0] += s

    bucket = TokenBucket(1e9, 1e9, 1, clock=clock, sleep=sleep)

    def admission(est):
        calls.append(est)
        return bucket.acquire(est)

    sched, _ = _scheduler(admission=admission)
    sched.submit(Request(0, prompt_tokens=[4, 5], max_new_tokens=3))
    sched.run_to_completion()
    assert calls == [5]  # prompt 2 + max_new 3
