"""Multi-replica serving fabric (ISSUE 7): router policy determinism,
replica-count byte-parity, global single-flight across replicas,
replica-failure drain, per-replica stats aggregation, and the
prefill/decode admission split."""

import threading

import pytest

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    InferenceRequest,
    InferenceService,
    MetricConfig,
    SimulatedAPIEngine,
    SimulatedSlotEngine,
    StatisticsConfig,
)
from repro.core.engines import EngineRegistry
from repro.core.service import ReplicaRouter, ReplicaView
from repro.data import mixed_examples

API_MODEL = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")
SLOT_MODEL = EngineModelConfig(provider="slotsim", model_name="slot-sim")
SLOT_KW = {"n_slots": 4, "step_ms": 0.0}


def _task(task_id="rep", model=SLOT_MODEL, **inf_kw):
    return EvalTask(
        task_id=task_id,
        model=model,
        inference=InferenceConfig(batch_size=8, n_workers=4, **inf_kw),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    )


def _mv_tuple(mv):
    return (mv.value, mv.ci, mv.ci_method, mv.n, mv.n_unscored)


def _cmp_tuple(c):
    return (c.diff, c.diff_ci, c.test.p_value, c.effect.value)


def _views(loads):
    return [
        ReplicaView(index=i, queued=q, outstanding=o)
        for i, (q, o) in enumerate(loads)
    ]


# -- router units ---------------------------------------------------------------


def test_least_loaded_picks_min_load_breaking_ties_low_index():
    r = ReplicaRouter("least_loaded")
    assert r.route("p", _views([(2, 1), (0, 1), (4, 0)])) == 1
    # tie on total load -> lowest index
    assert r.route("p", _views([(1, 1), (0, 2), (2, 0)])) == 0
    # placement is a pure function of the load snapshot
    for _ in range(5):
        assert r.route("x", _views([(3, 3), (1, 0), (1, 1)])) == 1


def test_prefix_affinity_is_deterministic_and_prefix_only():
    r = ReplicaRouter("prefix_affinity", prefix_len=16)
    views = _views([(0, 0)] * 4)
    header = "Few-shot header #7: "  # > prefix_len once suffixed
    picks = {
        r.route(header + suffix, views)
        for suffix in ("alpha", "beta", "gamma", "delta")
    }
    # same prefix -> same replica regardless of suffix or load
    assert len(picks) == 1
    assert r.route(header + "epsilon", _views([(9, 9)] * 4)) == picks.pop()
    # repeated routing never drifts
    assert r.route("abc", views) == r.route("abc", views)


def test_prefix_affinity_spreads_distinct_prefixes():
    r = ReplicaRouter("prefix_affinity", prefix_len=64)
    views = _views([(0, 0)] * 4)
    picks = {r.route(f"prompt family {i}: body", views) for i in range(64)}
    assert len(picks) > 1  # a hash that pins everything to one replica is a bug


def test_round_robin_rotates_over_alive_replicas():
    r = ReplicaRouter("round_robin")
    views = _views([(0, 0)] * 3)
    assert [r.route("p", views) for _ in range(6)] == [0, 1, 2, 0, 1, 2]


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown routing policy"):
        ReplicaRouter("random")


def test_registry_keys_replicas_separately():
    reg = EngineRegistry()
    e0 = reg.get(SLOT_MODEL, replica=0, **SLOT_KW)
    e1 = reg.get(SLOT_MODEL, replica=1, **SLOT_KW)
    assert e0 is not e1
    assert reg.get(SLOT_MODEL, replica=0, **SLOT_KW) is e0
    assert len(reg) == 2
    reg.shutdown()


# -- byte-identical parity across replica counts --------------------------------


@pytest.mark.parametrize("routing", ["least_loaded", "prefix_affinity"])
def test_replica_count_parity_suite_output(routing):
    """1 vs 2 vs 4 replicas: metrics, CIs and the significance matrix are
    byte-identical — routing is stats-plane-invisible."""
    rows = mixed_examples(60, seed=5)
    models = [
        SLOT_MODEL,
        EngineModelConfig(provider="slotsim", model_name="slot-sim-b"),
    ]

    def run(n_replicas):
        suite = (
            EvalSuite(f"rep{n_replicas}")
            .add_task(
                _task(n_replicas=n_replicas, routing=routing), rows
            )
            .sweep_models(models)
        )
        with EvalSession(engine_kwargs=SLOT_KW) as session:
            res = session.run_suite(suite, parallel_jobs=2)
            snaps = session.serving_stats()
        return res, snaps

    base, _ = run(1)
    for n in (2, 4):
        got, snaps = run(n)
        for key, res in base.results.items():
            assert got.results[key].responses == res.responses, key
            for m, mv in res.metrics.items():
                assert _mv_tuple(got.results[key].metrics[m]) == _mv_tuple(mv)
        for task_id, metrics in base.comparisons.items():
            for metric, cells in metrics.items():
                for pair, cmp in cells.items():
                    assert _cmp_tuple(
                        got.comparisons[task_id][metric][pair]
                    ) == _cmp_tuple(cmp), (task_id, metric, pair)
        for snap in snaps:
            assert snap["replicas"] == n
            assert len(snap["replica_stats"]) == n


def test_replica_aggregation_invariants():
    """Fleet-aggregated batcher counters keep the single-replica
    invariants: admissions == dispatched, completions == completed, and
    every replica that got traffic shows its own slice."""
    rows = mixed_examples(48, seed=9)
    with EvalSession(engine_kwargs=SLOT_KW) as session:
        session.run_task(rows, _task(n_replicas=3, routing="round_robin"))
        (snap,) = session.serving_stats()
    assert snap["mode"] == "batcher" and snap["replicas"] == 3
    b = snap["batcher"]
    assert b["admissions"] == snap["dispatched"]
    assert b["completions"] == snap["completed"]
    assert 0.0 < b["slot_occupancy"] <= 1.0
    per = snap["replica_stats"]
    assert sum(r["dispatched"] for r in per) == snap["dispatched"]
    assert sum(r["completed"] for r in per) == snap["completed"]
    assert all(r["routed"] > 0 for r in per)  # round-robin touched them all
    assert sum(
        r["batcher"]["admissions"] for r in per
    ) == b["admissions"]


# -- global single-flight -------------------------------------------------------


class GatedEngine(SimulatedAPIEngine):
    def __init__(self, model, gate, **kw):
        super().__init__(model, **kw)
        self.gate = gate

    def infer(self, request):
        assert self.gate.wait(10.0), "test gate never opened"
        return super().infer(request)


def test_single_flight_is_global_across_replicas():
    """Duplicate in-flight keys coalesce BEFORE routing: one engine call
    total across the whole fleet, no matter how many replicas exist."""
    gate = threading.Event()
    fleet = [GatedEngine(API_MODEL, gate) for _ in range(4)]
    for e in fleet:
        e.initialize()
    svc = InferenceService(
        engines=fleet, routing="round_robin", n_dispatchers=2, name="fleet"
    )
    req = InferenceRequest("the same expensive prompt", 16, 0.0)
    tickets = [svc.submit(req, key="dup") for _ in range(8)]
    assert tickets[0].primary and not any(t.primary for t in tickets[1:])
    gate.set()
    texts = {t.result(timeout=10.0).text for t in tickets}
    assert len(texts) == 1
    assert sum(e.calls for e in fleet) == 1
    snap = svc.snapshot()
    assert snap["submitted"] == 8 and snap["coalesced"] == 7
    assert snap["dispatched"] == 1
    svc.close()


def test_distinct_keys_spread_across_replicas():
    fleet = [SimulatedAPIEngine(API_MODEL) for _ in range(2)]
    for e in fleet:
        e.initialize()
    svc = InferenceService(engines=fleet, routing="round_robin")
    tickets = [
        svc.submit(InferenceRequest(f"unique {i}", 8, 0.0), key=f"k{i}")
        for i in range(10)
    ]
    for t in tickets:
        assert t.result(timeout=10.0).error is None
    assert [e.calls for e in fleet] == [5, 5]
    svc.close()


# -- replica failure ------------------------------------------------------------


class DyingSlotEngine(SimulatedSlotEngine):
    """Slot engine whose decode loop can be killed mid-flight."""

    def __init__(self, model, **kw):
        super().__init__(model, **kw)
        self.die = threading.Event()

    def stream_pump(self):
        if self.die.is_set():
            raise RuntimeError("replica hardware fault")
        return super().stream_pump()


def test_dead_replica_fails_its_tickets_without_stranding_gathers():
    sick = DyingSlotEngine(SLOT_MODEL, **SLOT_KW)
    healthy = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW)
    sick.initialize(), healthy.initialize()
    svc = InferenceService(
        engines=[sick, healthy], routing="round_robin",
        max_batch_wait_ms=0.0, name="split",
    )
    sick.die.set()
    tickets = [
        svc.submit(InferenceRequest(f"prompt {i}", 8, 0.0), key=f"k{i}")
        for i in range(8)
    ]
    ok = fail = 0
    for t in tickets:
        try:
            resp = t.result(timeout=10.0)
            assert resp.error is None
            ok += 1
        except RuntimeError as e:
            assert "hardware fault" in str(e)
            fail += 1
    assert ok >= 1 and fail >= 1  # both replicas saw traffic, none stranded
    # the fleet keeps serving: new work routes around the dead replica
    late = [
        svc.submit(InferenceRequest(f"late {i}", 8, 0.0), key=f"l{i}")
        for i in range(4)
    ]
    for t in late:
        assert t.result(timeout=10.0).error is None
    snap = svc.snapshot()
    per = {r["index"]: r for r in snap["replica_stats"]}
    assert per[0]["broken"] and not per[1]["broken"]
    svc.close()


def test_whole_fleet_dead_breaks_the_service():
    fleet = [DyingSlotEngine(SLOT_MODEL, **SLOT_KW) for _ in range(2)]
    for e in fleet:
        e.initialize()
        e.die.set()
    svc = InferenceService(
        engines=fleet, routing="round_robin", max_batch_wait_ms=0.0
    )
    tickets = [
        svc.submit(InferenceRequest(f"doomed {i}", 8, 0.0), key=f"d{i}")
        for i in range(2)
    ]
    for t in tickets:
        with pytest.raises(RuntimeError, match="hardware fault"):
            t.result(timeout=10.0)
    # every replica broken -> the service refuses further submissions
    deadline = threading.Event()
    for _ in range(100):
        try:
            t = svc.submit(InferenceRequest("after the fall", 8, 0.0), key="x")
        except RuntimeError:
            break
        with pytest.raises(RuntimeError):
            t.result(timeout=10.0)
        deadline.wait(0.01)
    else:
        pytest.fail("service never reported the dead fleet")
    svc.close()


# -- session plumbing -----------------------------------------------------------


def test_session_builds_replica_fleet_from_inference_config():
    rows = mixed_examples(30, seed=11)
    with EvalSession(engine_kwargs=SLOT_KW) as session:
        res = session.run_task(rows, _task(n_replicas=3))
        (snap,) = session.serving_stats()
        assert snap["replicas"] == 3
        assert len(session.engines) == 3  # one registered engine per replica
    assert not res.failures


def test_suite_report_shows_replica_column():
    rows = mixed_examples(20, seed=13)
    suite = EvalSuite("repmd").add_task(_task(task_id="qa", n_replicas=2), rows)
    with EvalSession(engine_kwargs=SLOT_KW) as session:
        sres = session.run_suite(suite)
    md = sres.to_markdown()
    assert "## Inference service" in md
    assert "| replicas |" in md
    assert "| batcher | 2 " in md  # the engine row carries its fleet size


# -- prefill/decode disaggregation ----------------------------------------------


def test_prefill_cap_defers_admissions_but_loses_nothing():
    eng = SimulatedSlotEngine(
        SLOT_MODEL, n_slots=4, step_ms=0.0, max_prefills_per_step=1
    )
    eng.initialize()
    rids = [
        eng.stream_submit(InferenceRequest(f"backlog {i}", 8, 0.0))
        for i in range(6)
    ]
    done = {}
    for _ in range(200):
        for rid, resp in eng.stream_pump():
            done[rid] = resp
        if len(done) == len(rids):
            break
    assert set(done) == set(rids)
    st = eng.stats
    assert st.admissions == 6
    assert st.prefills_deferred > 0  # the cap actually bit
    assert st.completions == 6


def test_prefill_cap_output_parity_with_uncapped():
    prompts = [f"identical workload {i}" for i in range(10)]

    def run(cap):
        eng = SimulatedSlotEngine(
            SLOT_MODEL, n_slots=4, step_ms=0.0, max_prefills_per_step=cap
        )
        eng.initialize()
        rids = {eng.stream_submit(InferenceRequest(p, 8, 0.0)): p
                for p in prompts}
        out = {}
        while eng.stream_pending():
            for rid, resp in eng.stream_pump():
                out[rids[rid]] = resp.text
        return out

    assert run(0) == run(1) == run(2)
