"""Certifiable adaptive evaluation (ISSUE 6): per-task early stopping in
both streaming pipelines, the manifest stop/regime contract (bit-identical
crash-resume of a stopped task, refusal on regime changes), and the
suite-level inference-budget scheduler."""

import dataclasses as dc

import pytest

from repro.core import (
    BudgetConfig,
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    ManifestMismatch,
    MetricConfig,
    StatisticsConfig,
    run_adaptive_suite,
)
from repro.data import iter_qa_examples
from repro.ft import ChunkCrashMiddleware, Fault, SimulatedCrash
from repro.storage.spill import ChunkManifest
from repro.stats.sequential import StoppingRule

M = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")
M_STRONG = EngineModelConfig(provider="openai", model_name="gpt-4o")
M_WEAK = EngineModelConfig(provider="openai", model_name="gpt-3.5-turbo")


def _task(task_id="adapt", **stream_kw) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        model=M,
        inference=InferenceConfig(batch_size=32, n_workers=3, cache_dir=""),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    ).with_streaming(max_memory_rows=50, **stream_kw)


#: stops at chunk 2 (n=150) of the 400-example sources used below
RULE = dict(min_examples=100, max_examples=150)


# -- per-task early stopping ---------------------------------------------------


def test_serial_early_stop_consumes_partial_source(tmp_path):
    task = _task(spill_dir=str(tmp_path / "s")).with_stopping(**RULE)
    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(400, seed=1), task)
        assert session.accounting.engine_calls == 150
    log = res.logs["streaming"]
    assert log["n_examples"] == 150 and log["n_chunks"] == 3
    ad = res.logs["adaptive"]
    assert ad["stopped"] and ad["reason"] == "max_examples"
    assert ad["stop_chunk"] == 2 and ad["n_examples"] == 150
    # the stop decision is durable manifest state, not just a log line
    from repro.core.streaming import _run_key

    manifest = ChunkManifest(str(tmp_path / "s"), _run_key(task))
    stop = manifest.stop_row()
    assert stop is not None and int(stop["stop_chunk"]) == 2
    assert stop["rule"] == task.stopping.fingerprint()


def test_width_stop_fires_when_interval_is_tight(tmp_path):
    # exact_match of the simulated engine is constantly 0 here, so its
    # acs interval collapses fast: watch that metric with a loose target
    task = _task(spill_dir=str(tmp_path / "s")).with_stopping(
        metric="exact_match", target_half_width=0.2, min_examples=100
    )
    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(400, seed=2), task)
    ad = res.logs["adaptive"]
    assert ad["stopped"] and ad["reason"] == "target_half_width"
    assert ad["half_width"] <= 0.2
    assert res.logs["streaming"]["n_examples"] < 400


def test_stopped_run_resumes_bit_identical_and_never_reopens(tmp_path):
    task = _task(spill_dir=str(tmp_path / "s")).with_stopping(**RULE)

    # crash after chunk 1 committed, before the stop chunk ran
    crash = ChunkCrashMiddleware([Fault(shard=1, attempt=1)])
    with EvalSession(middleware=[crash]) as session:
        with pytest.raises(SimulatedCrash):
            session.run_task(iter_qa_examples(400, seed=3), task)

    # restart reaches the same certified stop, paying only chunk 2
    with EvalSession() as session:
        first = session.run_task(iter_qa_examples(400, seed=3), task)
        assert session.accounting.engine_calls == 50
    assert first.logs["adaptive"]["stop_chunk"] == 2

    # a completed stopped run replays for free and never re-opens sampling
    with EvalSession() as session:
        again = session.run_task(iter_qa_examples(400, seed=3), task)
        assert session.accounting.engine_calls == 0
    assert again.logs["adaptive"] == first.logs["adaptive"]
    assert again.logs["streaming"]["n_examples"] == 150
    for m, mv in first.metrics.items():
        assert again.metrics[m].value == mv.value
        assert again.metrics[m].ci == mv.ci


def test_concurrent_executor_stops_at_same_chunk_as_serial(tmp_path):
    serial = _task(spill_dir=str(tmp_path / "a")).with_stopping(**RULE)
    conc = _task(spill_dir=str(tmp_path / "b")).with_streaming(
        concurrency=3
    ).with_stopping(**RULE)
    with EvalSession() as session:
        ref = session.run_task(iter_qa_examples(400, seed=4), serial)
    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(400, seed=4), conc)
    assert res.logs["adaptive"]["stop_chunk"] == ref.logs["adaptive"]["stop_chunk"]
    assert res.logs["streaming"]["n_examples"] == 150
    for m, mv in ref.metrics.items():
        assert res.metrics[m].value == mv.value
        assert res.metrics[m].ci == mv.ci

    # in-flight chunks past the stop may have committed to the manifest;
    # a serial resume of that spill tolerates them (they are
    # deterministically excluded) and reproduces the identical result
    serial_on_b = dc.replace(conc, streaming=serial.streaming)
    serial_on_b = serial_on_b.with_streaming(spill_dir=str(tmp_path / "b"))
    with EvalSession() as session:
        replay = session.run_task(iter_qa_examples(400, seed=4), serial_on_b)
        assert session.accounting.engine_calls == 0
    for m, mv in res.metrics.items():
        assert replay.metrics[m].value == mv.value
        assert replay.metrics[m].ci == mv.ci


def test_changed_rule_refuses_resume_with_remediation_hint(tmp_path):
    task = _task(spill_dir=str(tmp_path / "s")).with_stopping(**RULE)
    with EvalSession() as session:
        session.run_task(iter_qa_examples(400, seed=5), task)
    retuned = task.with_stopping(max_examples=250)
    with EvalSession() as session:
        with pytest.raises(ManifestMismatch, match="clear the spill dir"):
            session.run_task(iter_qa_examples(400, seed=5), retuned)


def test_adaptive_and_exhaustive_regimes_never_mix(tmp_path):
    # adaptive spill resumed without a rule: refused
    task = _task(spill_dir=str(tmp_path / "a")).with_stopping(**RULE)
    with EvalSession() as session:
        session.run_task(iter_qa_examples(400, seed=6), task)
    plain = dc.replace(task, stopping=StoppingRule())
    with EvalSession() as session:
        with pytest.raises(ManifestMismatch, match="mix stopping regimes"):
            session.run_task(iter_qa_examples(400, seed=6), plain)

    # exhaustive spill resumed adaptively: refused (no regime row but
    # committed chunks exist)
    plain_b = _task(spill_dir=str(tmp_path / "b"))
    with EvalSession() as session:
        session.run_task(iter_qa_examples(200, seed=6), plain_b)
    with EvalSession() as session:
        with pytest.raises(ManifestMismatch, match="without adaptive"):
            session.run_task(
                iter_qa_examples(200, seed=6),
                plain_b.with_stopping(**RULE),
            )


def test_declared_cap_is_extendable_and_replayable(tmp_path):
    """StreamingConfig.max_examples is the budget scheduler's round cap:
    raising it resumes prior chunks, and re-running a *smaller* cap over
    the larger manifest replays without touching the extra chunks."""
    base = _task(spill_dir=str(tmp_path / "s"))
    with EvalSession() as session:
        r1 = session.run_task(
            iter_qa_examples(400, seed=7),
            base.with_streaming(max_examples=100),
        )
        assert session.accounting.engine_calls == 100
    with EvalSession() as session:
        r2 = session.run_task(
            iter_qa_examples(400, seed=7),
            base.with_streaming(max_examples=200),
        )
        assert session.accounting.engine_calls == 100  # only the new chunks
    assert r2.logs["streaming"]["n_resumed_chunks"] == 2
    with EvalSession() as session:
        r3 = session.run_task(
            iter_qa_examples(400, seed=7),
            base.with_streaming(max_examples=100),
        )
        assert session.accounting.engine_calls == 0
    for m, mv in r1.metrics.items():
        assert r3.metrics[m].value == mv.value
        assert r3.metrics[m].ci == mv.ci
    # an uncapped run over the same spill still refuses a shrunk source
    with EvalSession() as session:
        with pytest.raises(ManifestMismatch, match="beyond the end"):
            session.run_task(iter_qa_examples(100, seed=7), base)


# -- suite-level budget scheduler ----------------------------------------------


def _adaptive_suite(tmp_path, n=3000, task_id="qa", metrics=None):
    task = EvalTask(
        task_id=task_id,
        inference=InferenceConfig(batch_size=32, n_workers=2, cache_dir=""),
        metrics=metrics or (MetricConfig("token_f1"),),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    ).with_streaming(max_memory_rows=128, spill_dir=str(tmp_path / "spill"))
    return (
        EvalSuite("adaptive")
        .add_task(task, lambda: iter_qa_examples(n))
        .sweep_models([M_STRONG, M_WEAK])
    )


def test_budget_scheduler_certifies_separated_models_early(tmp_path):
    n = 3000
    budget = BudgetConfig(
        total_examples=4000, round_examples=256, min_examples=256,
        metric="token_f1",
    )
    with EvalSession() as session:
        res = run_adaptive_suite(session, _adaptive_suite(tmp_path, n), budget)
        # every fresh example is inferred exactly once across all rounds
        assert session.accounting.engine_calls == res.adaptive["budget"]["spent"]
    t = res.adaptive["tasks"]["qa"]
    assert t["certified"] and t["reason"] == "certified"
    assert t["verdicts"] == {"gpt-4o vs gpt-3.5-turbo": "a_better"}
    # certified well before exhausting either arm
    assert all(c < n for c in t["consumed"].values())
    assert res.adaptive["budget"]["spent"] <= budget.total_examples
    # the conventional significance machinery agrees on the direction
    cmp = res.comparison("qa", "token_f1", "gpt-4o", "gpt-3.5-turbo")
    assert cmp.diff > 0
    # report surfaces the adaptive table
    md = res.to_markdown()
    assert "## Adaptive evaluation" in md and "a_better" in md


def test_budget_scheduler_replay_reproduces_stop_state(tmp_path):
    budget = BudgetConfig(
        total_examples=4000, round_examples=256, min_examples=256,
        metric="token_f1",
    )
    with EvalSession() as session:
        r1 = run_adaptive_suite(session, _adaptive_suite(tmp_path), budget)
    with EvalSession() as session:
        r2 = run_adaptive_suite(session, _adaptive_suite(tmp_path), budget)
        assert session.accounting.engine_calls == 0  # pure manifest replay
    assert r1.adaptive["tasks"] == r2.adaptive["tasks"]
    assert r1.adaptive["budget"]["spent"] == r2.adaptive["budget"]["spent"]
    for key, res in r1.results.items():
        for m, mv in res.metrics.items():
            assert r2.results[key].metrics[m].value == mv.value
            assert r2.results[key].metrics[m].ci == mv.ci


def test_budget_exhaustion_leaves_task_undecided_not_wrong(tmp_path):
    # two near-identical models and a budget too small to separate them
    task = EvalTask(
        task_id="qa",
        inference=InferenceConfig(batch_size=32, n_workers=2, cache_dir=""),
        metrics=(MetricConfig("token_f1"),),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    ).with_streaming(max_memory_rows=128, spill_dir=str(tmp_path / "spill"))
    suite = (
        EvalSuite("tight")
        .add_task(task, lambda: iter_qa_examples(3000))
        .sweep_models([
            EngineModelConfig(provider="openai", model_name="gpt-4o"),
            EngineModelConfig(provider="anthropic", model_name="claude-3-5-sonnet"),
        ])
    )
    budget = BudgetConfig(
        total_examples=700, round_examples=128, min_examples=256,
        metric="token_f1",
    )
    with EvalSession() as session:
        res = run_adaptive_suite(session, suite, budget)
    t = res.adaptive["tasks"]["qa"]
    assert t["reason"] in ("budget_exhausted", "certified")
    if t["reason"] == "budget_exhausted":
        assert t["verdicts"]["gpt-4o vs claude-3-5-sonnet"] == "undecided"


def test_budget_scheduler_single_arm_width_target(tmp_path):
    task = EvalTask(
        task_id="solo",
        model=M,
        inference=InferenceConfig(batch_size=32, n_workers=2, cache_dir=""),
        metrics=(MetricConfig("token_f1"),),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    ).with_streaming(max_memory_rows=128, spill_dir=str(tmp_path / "spill"))
    suite = EvalSuite("solo").add_task(task, lambda: iter_qa_examples(4000))
    budget = BudgetConfig(
        total_examples=4000, round_examples=256, min_examples=256,
        target_half_width=0.05, metric="token_f1",
    )
    with EvalSession() as session:
        res = run_adaptive_suite(session, suite, budget)
    t = res.adaptive["tasks"]["solo"]
    assert t["certified"]
    assert t["half_width"] <= 0.05
    assert t["consumed"][M.model_name] < 4000


def test_budget_scheduler_validates_inputs(tmp_path):
    no_stream = EvalTask(task_id="t", metrics=(MetricConfig("token_f1"),))
    suite = EvalSuite("bad").add_task(no_stream, lambda: iter_qa_examples(10))
    budget = BudgetConfig(total_examples=100)
    with EvalSession() as session:
        with pytest.raises(ValueError, match="spill_dir"):
            run_adaptive_suite(session, suite, budget)

    streamed = no_stream.with_streaming(
        max_memory_rows=64, spill_dir=str(tmp_path / "s")
    )
    suite2 = EvalSuite("bad2").add_task(streamed, list(iter_qa_examples(10)))
    with EvalSession() as session:
        with pytest.raises(ValueError, match="factory"):
            run_adaptive_suite(session, suite2, budget)

    suite3 = EvalSuite("bad3").add_task(streamed, lambda: iter_qa_examples(10))
    with EvalSession() as session:
        with pytest.raises(ValueError, match="certifies on metric"):
            run_adaptive_suite(
                session, suite3,
                dc.replace(budget, metric="no_such_metric"),
            )
