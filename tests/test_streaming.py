"""Streaming bounded-memory evaluation: mergeable accumulators, Poisson
bootstrap, chunked pipeline equivalence, DeltaLite spill + crash-resume."""

import dataclasses as dc

import numpy as np
import pytest

from repro.core import (
    CostBudgetExceeded,
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    ManifestMismatch,
    MetricConfig,
    StatisticsConfig,
)
from repro.data import iter_chunks, iter_qa_examples, qa_examples
from repro.ft import ChunkCrashMiddleware, Fault, SimulatedCrash
from repro.stats import (
    MetricAccumulator,
    PoissonBootstrap,
    compute_ci,
    streaming_ci,
    t_interval,
    wilson_interval,
)

M = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")


def _task(task_id="stream", ci_method="percentile", **stream_kw) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        model=M,
        inference=InferenceConfig(batch_size=32, n_workers=3, cache_dir=""),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=300, ci_method=ci_method
        ),
    ).with_streaming(**stream_kw)


# -- accumulators --------------------------------------------------------------


def test_accumulator_chunked_merge_matches_full_update():
    rng = np.random.default_rng(0)
    scores = rng.random(1000)
    scores[::17] = np.nan
    full = MetricAccumulator()
    full.update(scores)
    merged = MetricAccumulator()
    for lo in range(0, 1000, 128):
        part = MetricAccumulator()
        part.update(scores[lo:lo + 128])
        merged.merge(MetricAccumulator.from_state(part.state()))
    assert merged.n == full.n
    assert merged.n_nan == full.n_nan
    assert merged.total == pytest.approx(full.total, rel=1e-12)
    ok = scores[~np.isnan(scores)]
    assert full.mean == pytest.approx(ok.mean())
    assert full.variance == pytest.approx(ok.var(ddof=1))


def test_poisson_bootstrap_order_independent_and_serializable():
    rng = np.random.default_rng(1)
    scores = rng.random(600)
    starts = [0, 200, 400]
    fwd = PoissonBootstrap(100, seed=7)
    for s in starts:
        fwd.update(scores[s:s + 200], s)
    rev = PoissonBootstrap(100, seed=7)
    for s in reversed(starts):
        part = PoissonBootstrap(100, seed=7)
        part.update(scores[s:s + 200], s)
        rev.merge(PoissonBootstrap.from_state(part.state()))
    np.testing.assert_allclose(fwd.means(), rev.means(), rtol=1e-12)
    with pytest.raises(ValueError):
        fwd.merge(PoissonBootstrap(100, seed=8))


def test_streaming_ci_analytical_matches_in_memory():
    rng = np.random.default_rng(2)
    scores = rng.random(400)
    acc = MetricAccumulator()
    acc.update(scores)
    iv = streaming_ci(acc, None, method="analytical")
    ref = t_interval(scores)
    assert iv.value == pytest.approx(ref.value, rel=1e-12)
    assert iv.lo == pytest.approx(ref.lo, rel=1e-9)
    assert iv.hi == pytest.approx(ref.hi, rel=1e-9)
    # binary -> Wilson, exactly
    binary = (rng.random(400) < 0.3).astype(np.float64)
    acc_b = MetricAccumulator()
    acc_b.update(binary)
    iv_b = streaming_ci(acc_b, None, method="analytical", binary=True)
    ref_b = wilson_interval(int(binary.sum()), 400)
    assert (iv_b.value, iv_b.lo, iv_b.hi) == (ref_b.value, ref_b.lo, ref_b.hi)


def test_poisson_ci_within_mc_tolerance_of_multinomial():
    rng = np.random.default_rng(3)
    scores = rng.random(1000)
    boot = PoissonBootstrap(1000, seed=0)
    acc = MetricAccumulator()
    for lo in range(0, 1000, 250):
        boot.update(scores[lo:lo + 250], lo)
        acc.update(scores[lo:lo + 250])
    iv = streaming_ci(acc, boot, method="percentile")
    ref = compute_ci(scores, method="percentile", n_boot=1000)
    width = ref.hi - ref.lo
    assert iv.lo == pytest.approx(ref.lo, abs=0.5 * width)
    assert iv.hi == pytest.approx(ref.hi, abs=0.5 * width)


# -- streaming pipeline --------------------------------------------------------


def test_streaming_matches_in_memory_run(tmp_path):
    rows = qa_examples(400, seed=5)
    with EvalSession() as session:
        mem = session.run_task(rows, _task(enabled=False))
    with EvalSession() as session:
        stream = session.run_task(
            iter(rows), _task(max_memory_rows=64, spill_dir=str(tmp_path / "sp"))
        )
    for m, mv in mem.metrics.items():
        sv = stream.metrics[m]
        assert sv.value == pytest.approx(mv.value, abs=1e-5)
        assert sv.n == mv.n
        assert sv.n_unscored == mv.n_unscored
        width = max(mv.ci[1] - mv.ci[0], 1e-6)
        assert sv.ci[0] == pytest.approx(mv.ci[0], abs=width)
        assert sv.ci[1] == pytest.approx(mv.ci[1], abs=width)
    assert stream.engine_stats["calls"] == mem.engine_stats["calls"] == 400
    log = stream.logs["streaming"]
    assert log["n_examples"] == 400
    assert log["n_chunks"] == 7  # ceil(400/64)
    assert log["max_resident_rows"] == 64  # O(chunk), not O(dataset)
    # raw per-example state is discarded
    assert stream.responses == []
    assert stream.scores == {}


def test_streaming_analytical_ci_identical(tmp_path):
    rows = qa_examples(200, seed=6)
    with EvalSession() as session:
        mem = session.run_task(rows, _task(ci_method="analytical", enabled=False))
    with EvalSession() as session:
        stream = session.run_task(iter(rows), _task(
            ci_method="analytical", max_memory_rows=50,
        ))
    for m, mv in mem.metrics.items():
        sv = stream.metrics[m]
        assert sv.ci_method == mv.ci_method
        assert sv.value == pytest.approx(mv.value, rel=1e-9)
        assert sv.ci[0] == pytest.approx(mv.ci[0], rel=1e-6, abs=1e-9)
        assert sv.ci[1] == pytest.approx(mv.ci[1], rel=1e-6, abs=1e-9)


def test_streaming_rejects_custom_stages():
    with EvalSession() as session:
        with pytest.raises(ValueError):
            session.run_task(
                [], _task(), stages=[],
            )


def test_cost_budget_aborts_between_chunks(tmp_path):
    with EvalSession(cost_budget_usd=1e-9) as session:
        with pytest.raises(CostBudgetExceeded, match="chunk"):
            session.run_task(iter_qa_examples(200, seed=7), _task(
                max_memory_rows=50,
            ))


# -- spill + resume ------------------------------------------------------------


def test_crash_resume_skips_committed_chunks(tmp_path):
    """Kill a streaming run mid-way (deterministic injection), restart,
    assert completed chunks are skipped and metrics match an uninterrupted
    run exactly."""
    n, chunk = 400, 50
    task = _task(max_memory_rows=chunk, spill_dir=str(tmp_path / "spill"))

    # uninterrupted reference in its own spill dir
    ref_task = _task(max_memory_rows=chunk, spill_dir=str(tmp_path / "ref"))
    with EvalSession() as session:
        ref = session.run_task(iter_qa_examples(n, seed=8), ref_task)

    # crash after chunk 3 committed (chunks 0..3 done, 4..7 pending)
    crash = ChunkCrashMiddleware([Fault(shard=3, attempt=1)])
    with EvalSession(middleware=[crash]) as session:
        with pytest.raises(SimulatedCrash):
            session.run_task(iter_qa_examples(n, seed=8), task)
        assert session.accounting.engine_calls == 4 * chunk
    assert crash.injected == [(3, 1, "raise")]

    # restart: committed chunks must not re-run (no engine calls for them)
    with EvalSession(middleware=[crash]) as session:
        res = session.run_task(iter_qa_examples(n, seed=8), task)
        assert session.accounting.engine_calls == n - 4 * chunk
    log = res.logs["streaming"]
    assert log["n_resumed_chunks"] == 4
    assert log["n_chunks"] == 8
    for m, mv in ref.metrics.items():
        assert res.metrics[m].value == mv.value
        assert res.metrics[m].ci == mv.ci
    assert res.engine_stats["calls"] == ref.engine_stats["calls"] == n


def test_completed_run_resumes_with_zero_engine_calls(tmp_path):
    task = _task(max_memory_rows=100, spill_dir=str(tmp_path / "spill"))
    with EvalSession() as session:
        first = session.run_task(iter_qa_examples(300, seed=9), task)
    with EvalSession() as session:
        again = session.run_task(iter_qa_examples(300, seed=9), task)
        assert session.accounting.engine_calls == 0
    assert again.logs["streaming"]["n_resumed_chunks"] == 3
    # resumed chunks still count toward peak resident rows (digest check
    # materializes them)
    assert again.logs["streaming"]["max_resident_rows"] == 100
    for m, mv in first.metrics.items():
        assert again.metrics[m].value == mv.value
        assert again.metrics[m].ci == mv.ci
    # retuning execution knobs on restart must not orphan committed chunks
    retuned = dc.replace(
        task, inference=InferenceConfig(batch_size=8, n_workers=2, cache_dir="")
    )
    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(300, seed=9), retuned)
        assert session.accounting.engine_calls == 0
    assert res.logs["streaming"]["n_resumed_chunks"] == 3


def test_manifest_mismatch_on_different_source(tmp_path):
    task = _task(max_memory_rows=100, spill_dir=str(tmp_path / "spill"))
    with EvalSession() as session:
        session.run_task(iter_qa_examples(300, seed=10), task)
    with EvalSession() as session:
        with pytest.raises(ManifestMismatch):
            # same task fingerprint, shorter source: last chunk disagrees
            session.run_task(iter_qa_examples(250, seed=10), task)
    with EvalSession() as session:
        with pytest.raises(ManifestMismatch):
            # same shape, different content: chunk digest disagrees
            session.run_task(iter_qa_examples(300, seed=99), task)
    with EvalSession() as session:
        with pytest.raises(ManifestMismatch, match="beyond the end"):
            # chunk-aligned shrink: trailing committed chunks must not be
            # silently dropped
            session.run_task(iter_qa_examples(200, seed=10), task)


def test_with_streaming_preserves_prior_fields():
    task = _task(max_memory_rows=256).with_streaming(spill_dir="/tmp/x")
    assert task.streaming.max_memory_rows == 256  # not reset to the default
    assert task.streaming.spill_dir == "/tmp/x"
    assert task.streaming.enabled


def test_streaming_ci_rejects_unknown_method():
    acc = MetricAccumulator()
    acc.update(np.arange(10, dtype=np.float64))
    with pytest.raises(ValueError, match="unknown ci method"):
        streaming_ci(acc, PoissonBootstrap(10), method="bac")


def test_streaming_throughput_uses_example_count(tmp_path):
    with EvalSession() as session:
        res = session.run_task(
            iter_qa_examples(200, seed=14), _task(max_memory_rows=50)
        )
    assert res.responses == []
    assert 0 < res.throughput_per_min < float("inf")


def test_resume_disabled_reruns_everything(tmp_path):
    task = _task(
        max_memory_rows=100, spill_dir=str(tmp_path / "spill"), resume=False
    )
    with EvalSession() as session:
        session.run_task(iter_qa_examples(200, seed=11), task)
    with EvalSession() as session:
        res = session.run_task(iter_qa_examples(200, seed=11), task)
        assert session.accounting.engine_calls == 200
    assert res.logs["streaming"]["n_resumed_chunks"] == 0
    # flipping execution-strategy knobs must not orphan committed chunks:
    # the resume key normalizes resume/spill_dir away
    with EvalSession() as session:
        res = session.run_task(
            iter_qa_examples(200, seed=11), task.with_streaming(resume=True)
        )
        assert session.accounting.engine_calls == 0
    assert res.logs["streaming"]["n_resumed_chunks"] == 2


# -- suite integration ---------------------------------------------------------


def test_streaming_task_in_suite_with_callable_source(tmp_path):
    m_b = EngineModelConfig(provider="anthropic", model_name="claude-3-haiku")
    suite = (
        EvalSuite("stream-suite")
        .add_task(_task(max_memory_rows=64), lambda: iter_qa_examples(150, seed=12))
        .sweep_models([M, m_b])
    )
    with EvalSession() as session:
        res = session.run_suite(suite)
    assert len(res.results) == 2
    for label in res.models:
        r = res.result(label, "stream")
        assert r.logs["streaming"]["n_examples"] == 150
        assert set(r.metrics) == {"exact_match", "token_f1"}
        assert not r.scores  # per-example scores still never materialized
    # streaming tasks no longer opt out of pairwise significance: the
    # paired-delta bootstrap over shared weight streams fills the matrix
    for metric in ("exact_match", "token_f1"):
        cmp = res.comparison("stream", metric, *res.models)
        assert cmp.test.test == "paired_bootstrap"
        assert cmp.n == 150
        assert 0.0 < cmp.test.p_value <= 1.0
        assert cmp.diff_ci[0] <= cmp.diff <= cmp.diff_ci[1]


def test_streaming_suite_analytical_ci_warns_no_replicates():
    m_b = EngineModelConfig(provider="anthropic", model_name="claude-3-haiku")
    suite = (
        EvalSuite("stream-suite")
        .add_task(
            _task(ci_method="analytical", max_memory_rows=64),
            lambda: iter_qa_examples(120, seed=5),
        )
        .sweep_models([M, m_b])
    )
    with EvalSession() as session:
        with pytest.warns(UserWarning, match="not paired-comparable"):
            res = session.run_suite(suite)
    assert res.comparisons == {"stream": {}}


def test_iter_chunks_shapes():
    chunks = list(iter_chunks(iter_qa_examples(25, seed=0), 10))
    assert [len(c) for c in chunks] == [10, 10, 5]
    assert sum(chunks, []) == qa_examples(25, seed=0)
    with pytest.raises(ValueError):
        list(iter_chunks([], 0))
