"""Statistics vs scipy oracles + hypothesis property tests."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from scipy import stats as ss

from repro.stats import (
    bca_bootstrap,
    mcnemar_test,
    paired_t_test,
    percentile_bootstrap,
    permutation_test,
    recommend_test,
    shapiro_wilk,
    t_interval,
    wilcoxon_signed_rank,
    wilson_interval,
)
from repro.stats.special import (
    binom_test_two_sided,
    chi2_sf,
    gammainc,
    norm_ppf,
    t_cdf,
    t_ppf,
)

# ---------------------------------------------------------------------------
# special functions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("x,df", [(1.5, 10), (-2.3, 4), (0.2, 99), (4.1, 30), (0.0, 7)])
def test_t_cdf_vs_scipy(x, df):
    assert abs(t_cdf(x, df) - ss.t.cdf(x, df)) < 1e-12


@pytest.mark.parametrize("x,df", [(3.2, 1), (0.5, 2), (10.0, 5), (25.0, 3)])
def test_chi2_sf_vs_scipy(x, df):
    assert abs(chi2_sf(x, df) - ss.chi2.sf(x, df)) < 1e-12


@pytest.mark.parametrize("p", [0.001, 0.025, 0.5, 0.975, 0.999])
def test_ppf_vs_scipy(p):
    assert abs(norm_ppf(p) - ss.norm.ppf(p)) < 1e-12
    assert abs(t_ppf(p, 7) - ss.t.ppf(p, 7)) < 1e-7


def test_gammainc_vs_scipy():
    from scipy import special as sp

    for a, x in [(0.5, 0.3), (3.0, 2.0), (10.0, 14.0)]:
        assert abs(gammainc(a, x) - sp.gammainc(a, x)) < 1e-12


def test_exact_binom_vs_scipy():
    for k, n in [(2, 10), (0, 5), (7, 9), (5, 10)]:
        assert abs(
            binom_test_two_sided(k, n) - ss.binomtest(k, n, 0.5).pvalue
        ) < 1e-12


# ---------------------------------------------------------------------------
# significance tests
# ---------------------------------------------------------------------------


def test_paired_t_vs_scipy(rng):
    a = rng.normal(0.5, 1.0, 100)
    b = a + rng.normal(0.1, 0.5, 100)
    ours = paired_t_test(a, b)
    sp = ss.ttest_rel(a, b)
    assert abs(ours.p_value - sp.pvalue) < 1e-10
    assert abs(ours.statistic - sp.statistic) < 1e-10


def test_wilcoxon_vs_scipy(rng):
    a = rng.normal(0.5, 1.0, 100)
    b = a + rng.normal(0.05, 0.4, 100)
    ours = wilcoxon_signed_rank(a, b)
    sp = ss.wilcoxon(a, b, correction=True)
    assert abs(ours.p_value - sp.pvalue) < 1e-9


def test_wilcoxon_exact_vs_scipy(rng):
    a = rng.normal(0, 1, 14)
    b = a + rng.normal(0.3, 0.6, 14)
    ours = wilcoxon_signed_rank(a, b)
    sp = ss.wilcoxon(a, b, mode="exact")
    assert ours.test == "wilcoxon_exact"
    assert abs(ours.p_value - sp.pvalue) < 1e-9


def test_mcnemar_exact_small_discordant():
    a = np.array([1, 1, 1, 0, 0, 1, 1, 1] + [1] * 20, bool)
    b = np.array([1, 0, 1, 0, 1, 1, 1, 1] + [1] * 20, bool)
    res = mcnemar_test(a, b)
    assert res.test == "mcnemar_exact"
    # 2 discordant pairs, 1 each way -> p = 1
    assert res.p_value == 1.0


def test_mcnemar_chi2_path(rng):
    a = rng.rand(500) < 0.8
    b = rng.rand(500) < 0.6
    res = mcnemar_test(a, b)
    assert res.test == "mcnemar"
    assert res.p_value < 0.01  # clearly different marginals


def test_shapiro_wilk_vs_scipy(rng):
    for dist in (rng.normal(0, 1, 60), rng.lognormal(0, 0.8, 60)):
        w, p = shapiro_wilk(dist)
        sp = ss.shapiro(dist)
        assert abs(w - sp.statistic) < 2e-3
        # p-values agree in decision at alpha=0.05 and in magnitude
        assert (p < 0.05) == (sp.pvalue < 0.05)


def test_permutation_null_uniformish(rng):
    ps = []
    for i in range(40):
        d = rng.normal(0, 1, 30)
        ps.append(permutation_test(d, np.zeros(30), n_perm=200, seed=i).p_value)
    assert 0.2 < np.mean(ps) < 0.8  # not degenerate under the null


# ---------------------------------------------------------------------------
# intervals
# ---------------------------------------------------------------------------


def test_t_interval_vs_scipy(rng):
    a = rng.normal(3, 2, 50)
    iv = t_interval(a)
    lo, hi = ss.t.interval(0.95, 49, loc=a.mean(), scale=ss.sem(a))
    assert abs(iv.lo - lo) < 1e-10 and abs(iv.hi - hi) < 1e-10


def test_wilson_vs_known():
    iv = wilson_interval(8, 10)
    # hand-computed Wilson score bounds at z=1.95996
    assert abs(iv.lo - 0.49016) < 2e-4 and abs(iv.hi - 0.94332) < 2e-4
    edge = wilson_interval(0, 20)
    assert edge.lo == 0.0 and edge.hi < 0.2


def test_bootstrap_cis_bracket_mean(rng):
    a = rng.lognormal(0, 0.5, 200)
    for fn in (percentile_bootstrap, bca_bootstrap):
        iv = fn(a, n_boot=400, seed=3)
        assert iv.lo < a.mean() < iv.hi
        assert iv.hi - iv.lo < 4 * a.std() / np.sqrt(len(a)) * 2


def test_recommendation_table2(rng):
    bin_a = (rng.rand(50) < 0.5).astype(float)
    bin_b = (rng.rand(50) < 0.5).astype(float)
    assert recommend_test(bin_a, bin_b).test == "mcnemar"
    norm_a = rng.normal(0, 1, 100)
    assert recommend_test(norm_a, norm_a + rng.normal(0, 1, 100)).test == "paired_t"
    skew = rng.lognormal(0, 1.2, 100)
    assert recommend_test(skew, skew * rng.lognormal(0, 1.0, 100)).test == "wilcoxon"


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

finite_arrays = st.lists(
    st.floats(-1e3, 1e3, allow_nan=False), min_size=8, max_size=60
)


@given(finite_arrays, finite_arrays)
@settings(max_examples=25, deadline=None)
def test_pvalues_in_range(xs, ys):
    n = min(len(xs), len(ys))
    a, b = np.asarray(xs[:n]), np.asarray(ys[:n])
    for res in (
        paired_t_test(a, b),
        wilcoxon_signed_rank(a, b),
        permutation_test(a, b, n_perm=50),
    ):
        assert 0.0 <= res.p_value <= 1.0


@given(finite_arrays)
@settings(max_examples=25, deadline=None)
def test_identical_samples_not_significant(xs):
    a = np.asarray(xs)
    for res in (paired_t_test(a, a), wilcoxon_signed_rank(a, a)):
        assert res.p_value > 0.9


@given(finite_arrays)
@settings(max_examples=20, deadline=None)
def test_interval_contains_point_estimate(xs):
    a = np.asarray(xs)
    iv = percentile_bootstrap(a, n_boot=100, seed=1)
    assert iv.lo - 1e-6 <= np.float32(a.mean()) * 1.0 + 1e-6 >= iv.lo  # sanity
    assert iv.lo <= iv.hi


@given(st.integers(0, 30), st.integers(1, 30))
@settings(max_examples=30, deadline=None)
def test_wilson_bounds(k, n):
    k = min(k, n)
    iv = wilson_interval(k, n)
    assert 0.0 <= iv.lo <= iv.value <= iv.hi <= 1.0
