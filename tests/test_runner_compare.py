"""End-to-end EvalRunner: 4 stages, caching workflow, replay, comparison."""

import dataclasses as dc

import numpy as np
import pytest

from repro.core import (
    CachePolicy,
    Comparison,
    EngineModelConfig,
    EvalRunner,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    RunTracker,
    StatisticsConfig,
    compare_scores,
)
from repro.data import mixed_examples


def _task(tmp_path, **inf_kw) -> EvalTask:
    return EvalTask(
        task_id="t",
        model=EngineModelConfig(provider="openai", model_name="gpt-4o-mini"),
        inference=InferenceConfig(
            batch_size=8, n_workers=3, cache_dir=str(tmp_path / "cache"), **inf_kw
        ),
        metrics=(
            MetricConfig("exact_match"),
            MetricConfig("token_f1"),
            MetricConfig("llm_judge", type="llm_judge"),
        ),
        statistics=StatisticsConfig(bootstrap_iterations=200),
    )


def test_four_stages_and_cis(tmp_path):
    rows = mixed_examples(40, seed=3)
    res = EvalRunner().evaluate(rows, _task(tmp_path))
    assert set(res.metrics) == {"exact_match", "token_f1", "llm_judge"}
    for mv in res.metrics.values():
        if not np.isnan(mv.value):
            assert mv.ci[0] <= mv.value <= mv.ci[1]
    assert res.metrics["exact_match"].ci_method in ("bca", "wilson")
    assert len(res.responses) == 40
    assert res.timing["infer_s"] > 0


def test_cache_workflow_and_replay(tmp_path):
    rows = mixed_examples(30, seed=5)
    runner = EvalRunner()
    t1 = _task(tmp_path)
    r1 = runner.evaluate(rows, t1)
    r2 = runner.evaluate(rows, t1)
    assert r2.cache_stats["hit_rate"] == 1.0

    # replay: zero engine calls, identical metric scores
    t3 = dc.replace(
        t1, inference=dc.replace(t1.inference, cache_policy=CachePolicy.REPLAY)
    )
    r3 = runner.evaluate(rows, t3)
    np.testing.assert_array_equal(r1.scores["token_f1"], r3.scores["token_f1"])

    # replay on an empty cache raises
    t4 = dc.replace(
        t3, inference=dc.replace(t3.inference, cache_dir=str(tmp_path / "empty"))
    )
    with pytest.raises(Exception):
        runner.evaluate(rows, t4)


def test_failure_tracking(tmp_path):
    """Recoverable engine errors are retried; non-recoverable are recorded."""
    rows = mixed_examples(20, seed=9)
    task = _task(tmp_path, max_retries=0)
    # engine that fails every 5th call unrecoverably-ish (429 but no retries)
    res = EvalRunner().evaluate(rows, task)
    assert isinstance(res.failures, list)


def test_comparison_pipeline(rng):
    base = rng.rand(120)
    better = np.clip(base + 0.08 + rng.randn(120) * 0.02, 0, 1)
    cmp = compare_scores("m", better, base)
    assert isinstance(cmp, Comparison)
    assert cmp.diff > 0.05
    assert cmp.test.p_value < 1e-6
    assert cmp.diff_ci[0] > 0
    s = cmp.summary()
    assert "SIGNIFICANT" in s

    same = compare_scores("m", base, base.copy())
    assert same.test.p_value > 0.9


def test_binary_comparison_uses_mcnemar(rng):
    a = (rng.rand(200) < 0.8).astype(float)
    b = (rng.rand(200) < 0.6).astype(float)
    cmp = compare_scores("em", a, b)
    assert cmp.recommendation.test == "mcnemar"
    assert cmp.effect.name == "odds_ratio"
    assert cmp.test.p_value < 0.01


def test_tracking_roundtrip(tmp_path):
    rows = mixed_examples(15, seed=11)
    res = EvalRunner().evaluate(rows, _task(tmp_path))
    tracker = RunTracker(str(tmp_path / "runs"))
    run_id = tracker.log_run(_task(tmp_path), res, experiment="unit")
    assert run_id in tracker.list_runs()
    metrics = tracker.load_metrics(run_id)
    assert "token_f1" in metrics and "token_f1_ci_lower" in metrics
    tags = tracker.load_tags(run_id)
    assert tags["experiment"] == "unit"
