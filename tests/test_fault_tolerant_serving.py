"""Fault-tolerant serving (ISSUE 9): preemption instead of death under
page-pool pressure, bounded-backoff replica restart, the no-progress
health probe, request deadlines with hedged re-issue, the error taxonomy
(recoverable / per-ticket / replica-fatal), and the deterministic serving
chaos schedule — all under the byte-identity contract: faults cost work,
never correctness."""

import threading

import pytest

from repro.core import (
    EngineModelConfig,
    EvalSession,
    InferenceConfig,
    InferenceRequest,
    InferenceService,
    MetricConfig,
    RecoverableEngineError,
    SimulatedSlotEngine,
    StatisticsConfig,
)
from repro.core.config import EvalTask
from repro.data import mixed_examples
from repro.ft.failure_sim import ServingFault, ServingFaultSchedule
from repro.serve.paged_cache import PagePoolExhausted

SLOT_MODEL = EngineModelConfig(provider="slotsim", model_name="slot-sim")
SLOT_KW = {"n_slots": 4, "step_ms": 0.0}


def _pump_all(eng, rids, max_pumps=5000):
    done = {}
    for _ in range(max_pumps):
        for rid, resp in eng.stream_pump():
            done[rid] = resp
        if len(done) == len(rids):
            return done
    raise AssertionError(f"only {len(done)}/{len(rids)} completed")


def _texts(n=8, words=8):
    return [
        " ".join(f"w{i}t{j}" for j in range(words)) + f" tail {i}"
        for i in range(n)
    ]


def _mv_tuple(mv):
    return (mv.value, mv.ci, mv.ci_method, mv.n, mv.n_unscored)


# -- fault schedule -------------------------------------------------------------


def test_serving_fault_kind_is_validated():
    with pytest.raises(ValueError, match="unknown serving fault kind"):
        ServingFault(replica=0, step=1, kind="meteor_strike")


def test_schedule_attach_order_and_single_fire():
    plan = ServingFaultSchedule(
        [
            ServingFault(1, 5, "hang", duration=2),
            ServingFault(0, 3, "page_pressure"),
        ]
    )
    assert plan.attach() == 0 and plan.attach() == 1
    assert plan.poll(0, 2) is None  # before schedule
    f = plan.poll(0, 7)  # >= scheduled step: fires even if steps skipped
    assert f is not None and f.kind == "page_pressure"
    assert plan.poll(0, 8) is None  # each fault fires exactly once
    assert plan.poll(1, 5).kind == "hang"
    assert plan.injected == [(0, 7, "page_pressure"), (1, 5, "hang")]


# -- simulated engine: page gate and preemption ---------------------------------


def test_sim_page_gate_defers_prefills_and_stays_byte_identical():
    prompts = _texts(6, words=8)  # 10 words each -> 3 pages at page_size 4
    big = SimulatedSlotEngine(SLOT_MODEL, kv_page_size=4, **SLOT_KW)
    small = SimulatedSlotEngine(
        SLOT_MODEL, kv_page_size=4, page_pool=7, **SLOT_KW
    )
    for eng in (big, small):
        eng.initialize()
    out = {}
    for name, eng in (("big", big), ("small", small)):
        rids = [
            eng.stream_submit(InferenceRequest(p, 8, 0.0)) for p in prompts
        ]
        done = _pump_all(eng, rids)
        out[name] = [done[r].text for r in rids]
    assert out["small"] == out["big"]  # pressure never changes a byte
    assert small.stats.prefills_deferred > 0
    assert small.stats.completions == len(prompts)
    small._pages.check_no_leaks()


def test_sim_page_pressure_fault_preempts_and_recomputes_identically():
    prompts = _texts(8, words=8)
    plan = ServingFaultSchedule(
        [
            ServingFault(0, 2, "page_pressure", duration=2),
            ServingFault(0, 4, "page_pressure"),
        ]
    )
    faulted = SimulatedSlotEngine(
        SLOT_MODEL, kv_page_size=4, fault_plan=plan, **SLOT_KW
    )
    plain = SimulatedSlotEngine(SLOT_MODEL, kv_page_size=4, **SLOT_KW)
    for eng in (faulted, plain):
        eng.initialize()
    out = {}
    for eng in (plain, faulted):
        rids = [
            eng.stream_submit(InferenceRequest(p, 8, 0.0)) for p in prompts
        ]
        done = _pump_all(eng, rids)
        out[id(eng)] = [done[r].text for r in rids]
    assert out[id(faulted)] == out[id(plain)]
    assert faulted.stats.preemptions >= 3
    assert faulted.stats.preempted_tokens >= 0
    assert len(plan.injected) == 2
    faulted._pages.check_no_leaks()  # preemption released every page


def test_sim_prompt_larger_than_pool_raises_instead_of_deferring_forever():
    eng = SimulatedSlotEngine(
        SLOT_MODEL, kv_page_size=4, page_pool=2, **SLOT_KW
    )
    eng.initialize()
    eng.stream_submit(InferenceRequest(" ".join(["w"] * 40), 4, 0.0))
    with pytest.raises(PagePoolExhausted):
        for _ in range(50):
            eng.stream_pump()


# -- replica restart ------------------------------------------------------------


def test_replica_crash_mid_decode_restarts_and_reserves_byte_identically():
    prompts = _texts(8)
    plan = ServingFaultSchedule([ServingFault(0, 3, "replica_crash")])
    crashy = SimulatedSlotEngine(SLOT_MODEL, fault_plan=plan, **SLOT_KW)
    steady = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW)
    oracle = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW)
    svc = InferenceService(
        engines=[crashy, steady], routing="round_robin",
        max_batch_wait_ms=0.0, max_replica_restarts=2,
        restart_backoff_s=0.001, name="crashy",
    )
    tickets = [
        svc.submit(InferenceRequest(p, 8, 0.0), key=f"k{i}")
        for i, p in enumerate(prompts)
    ]
    got = [t.result(timeout=20.0) for t in tickets]
    expect = [oracle.infer(InferenceRequest(p, 8, 0.0)) for p in prompts]
    assert [r.text for r in got] == [r.text for r in expect]
    assert all(r.error is None for r in got)
    snap = svc.snapshot()
    assert snap["restarts"] >= 1 and snap["errors"] == 0
    per = {r["index"]: r for r in snap["replica_stats"]}
    assert not per[0]["broken"] and per[0]["restarts"] >= 1
    assert plan.injected == [(0, 3, "replica_crash")]
    # the restarted replica serves NEW work too, not just the carried work
    late = svc.submit(InferenceRequest(prompts[0], 8, 0.0), key="late")
    assert late.result(timeout=20.0).text == expect[0].text
    svc.close()


class AlwaysDying(SimulatedSlotEngine):
    """Crashes every pump, even after reset() — restarts cannot save it."""

    def stream_pump(self):
        raise RuntimeError(f"cursed replica (pump {self._pumps})")


def test_restart_budget_exhausted_fleet_report_names_every_replica():
    fleet = [AlwaysDying(SLOT_MODEL, **SLOT_KW) for _ in range(2)]
    svc = InferenceService(
        engines=fleet, routing="round_robin", max_batch_wait_ms=0.0,
        max_replica_restarts=1, restart_backoff_s=0.0, name="doomed",
    )
    tickets = [
        svc.submit(InferenceRequest(f"doomed {i}", 8, 0.0), key=f"d{i}")
        for i in range(2)
    ]
    for t in tickets:
        with pytest.raises(RuntimeError, match="cursed replica"):
            t.result(timeout=20.0)
    # S2: the fleet-dead error carries EVERY replica's first failure,
    # not just whichever replica died last
    wait = threading.Event()
    for _ in range(200):
        try:
            svc.submit(InferenceRequest("after the fall", 8, 0.0), key="x")
        except RuntimeError as e:
            msg = str(e)
            assert "replica 0:" in msg and "replica 1:" in msg
            assert "cursed replica" in msg
            assert "restarts 1" in msg
            break
        wait.wait(0.01)
    else:
        pytest.fail("service never reported the dead fleet")
    svc.close()


# -- health probe ---------------------------------------------------------------


def test_health_probe_catches_hung_replica_and_restart_recovers():
    prompts = _texts(4)
    plan = ServingFaultSchedule(
        [ServingFault(0, 2, "hang", duration=1_000_000)]
    )
    eng = SimulatedSlotEngine(SLOT_MODEL, fault_plan=plan, **SLOT_KW)
    oracle = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW)
    svc = InferenceService(
        engine=eng, max_batch_wait_ms=0.0, max_replica_restarts=1,
        restart_backoff_s=0.001, health_probe_steps=5, name="hung",
    )
    tickets = [
        svc.submit(InferenceRequest(p, 8, 0.0), key=f"h{i}")
        for i, p in enumerate(prompts)
    ]
    got = [t.result(timeout=20.0) for t in tickets]
    expect = [oracle.infer(InferenceRequest(p, 8, 0.0)) for p in prompts]
    assert [r.text for r in got] == [r.text for r in expect]
    assert svc.stats.restarts == 1  # the hang is invisible except to the probe
    svc.close()


def test_probe_disabled_by_default_short_hangs_self_recover():
    plan = ServingFaultSchedule([ServingFault(0, 2, "hang", duration=3)])
    eng = SimulatedSlotEngine(SLOT_MODEL, fault_plan=plan, **SLOT_KW)
    svc = InferenceService(engine=eng, max_batch_wait_ms=0.0)
    t = svc.submit(InferenceRequest("just slow", 8, 0.0), key="s")
    assert t.result(timeout=20.0).error is None
    assert svc.stats.restarts == 0
    svc.close()


# -- deadlines and hedged re-issue ----------------------------------------------


class WedgedEngine(SimulatedSlotEngine):
    """Accepts submissions, never completes them — and never raises, so
    only a deadline (or the health probe) can rescue its requests."""

    def stream_pump(self):
        return []


def test_deadline_hedges_to_another_replica_first_completion_wins():
    wedged = WedgedEngine(SLOT_MODEL, **SLOT_KW)
    steady = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW)
    oracle = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW)
    svc = InferenceService(
        engines=[wedged, steady], routing="round_robin",
        max_batch_wait_ms=0.0, name="hedged",
    )
    req = InferenceRequest("stuck prompt", 8, 0.0)
    t = svc.submit(req, key="hk", deadline_s=0.02)  # round-robin -> replica 0
    resp = t.result(timeout=20.0)
    assert resp.text == oracle.infer(req).text  # hedge changes replica, not bytes
    assert svc.stats.deadline_expiries == 1
    assert svc.stats.hedges_issued == 1
    assert svc.stats.hedges_won == 1
    assert svc.stats.completed == 1  # one flight, despite two legs
    # the losing leg is cancelled cooperatively: slot and queue entry freed
    wait = threading.Event()
    for _ in range(500):
        if svc.replicas[0].cancelled == 1:
            break
        wait.wait(0.01)
    assert svc.replicas[0].cancelled == 1
    assert not wedged.stream_pending()
    svc.close()


def test_no_deadline_means_no_hedging():
    eng = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW)
    svc = InferenceService(engine=eng, max_batch_wait_ms=0.0)
    t = svc.submit(InferenceRequest("calm", 8, 0.0), key="c")
    assert t.result(timeout=20.0).error is None
    assert svc.stats.deadline_expiries == 0
    assert svc.stats.hedges_issued == 0
    svc.close()


# -- error taxonomy (S1) --------------------------------------------------------


class TaxonomyEngine(SimulatedSlotEngine):
    """stream_submit: ValueError for 'bad' prompts, RecoverableEngineError
    for the first ``flake`` 'flaky' prompts, normal service otherwise."""

    def __init__(self, model, flake=1, **kw):
        super().__init__(model, **kw)
        self.flake = flake

    def stream_submit(self, request):
        if request.prompt.startswith("bad"):
            raise ValueError(f"malformed prompt: {request.prompt!r}")
        if request.prompt.startswith("flaky") and self.flake > 0:
            self.flake -= 1
            raise RecoverableEngineError("engine briefly overloaded")
        return super().stream_submit(request)


def test_value_error_fails_one_ticket_replica_lives_on():
    eng = TaxonomyEngine(SLOT_MODEL, **SLOT_KW)
    svc = InferenceService(engine=eng, max_batch_wait_ms=0.0)
    bad = svc.submit(InferenceRequest("bad {", 8, 0.0), key="b")
    with pytest.raises(ValueError, match="malformed prompt"):
        bad.result(timeout=20.0)
    good = svc.submit(InferenceRequest("good prompt", 8, 0.0), key="g")
    assert good.result(timeout=20.0).error is None
    assert svc.replicas[0].broken is None  # programming error != crash
    assert svc.stats.restarts == 0 and svc.stats.errors == 1
    svc.close()


def test_recoverable_error_retries_with_backoff_then_succeeds():
    eng = TaxonomyEngine(SLOT_MODEL, flake=2, **SLOT_KW)
    svc = InferenceService(engine=eng, max_batch_wait_ms=0.0)
    t = svc.submit(
        InferenceRequest("flaky prompt", 8, 0.0), key="f",
        max_retries=3, retry_delay=0.001,
    )
    assert t.result(timeout=20.0).error is None
    assert t.attempts == 3  # two refusals burned, third attempt served
    assert svc.replicas[0].broken is None
    svc.close()


def test_recoverable_error_exhausting_retries_fails_the_ticket_only():
    eng = TaxonomyEngine(SLOT_MODEL, flake=10, **SLOT_KW)
    svc = InferenceService(engine=eng, max_batch_wait_ms=0.0)
    t = svc.submit(
        InferenceRequest("flaky forever", 8, 0.0), key="f",
        max_retries=1, retry_delay=0.001,
    )
    with pytest.raises(RecoverableEngineError):
        t.result(timeout=20.0)
    ok = svc.submit(InferenceRequest("fine", 8, 0.0), key="o")
    assert ok.result(timeout=20.0).error is None
    svc.close()


# -- real batcher: decode-time pool exhaustion preempts, never kills ------------


def _batcher(n_slots=3, **kw):
    from repro.configs import ARCHS
    from repro.models import params as pm
    from repro.models.model import build_model
    from repro.serve import ContinuousBatcher

    import jax

    cfg = ARCHS["qwen3-4b"].reduced()
    model = build_model(cfg, remat="none")
    params = pm.init_params(jax.random.key(0), model.param_specs())
    return ContinuousBatcher(
        model, cfg, params, n_slots=n_slots, max_len=64, eos_id=1, **kw
    )


def test_batcher_pool_exhaustion_preempts_and_no_request_is_lost():
    """Regression: a page pool too small for the active set used to kill
    the whole replica with PagePoolExhausted mid-decode; now the victim
    slot is preempted and recomputed, byte-identically."""
    from repro.serve import Request

    reqs = [
        Request(i, prompt_tokens=[10 + i + j for j in range(14)],
                max_new_tokens=6)
        for i in range(5)
    ]

    def run(**kw):
        sched = _batcher(page_size=16, **kw)
        for r in reqs:
            sched.submit(r)
        done = {c.request_id: c for c in sched.run_to_completion()}
        return sched, done

    full, base = run()
    tight, pressured = run(page_pool=3)
    assert sorted(pressured) == list(range(5))  # zero lost requests
    assert all(
        c.finished_reason in ("eos", "length") for c in pressured.values()
    )
    assert tight.stats.preemptions >= 1
    assert tight.stats.prefills_deferred >= 1  # the admission gate held
    assert full.stats.preemptions == 0  # auto-sized pool never preempts
    for i in range(5):  # preemption costs recompute work, never bytes
        assert pressured[i].tokens == base[i].tokens
    tight.manager.check_no_leaks()


def test_batcher_cancel_releases_slot_and_pages():
    from repro.serve import Request

    sched = _batcher(page_size=16)
    for i in range(2):
        sched.submit(
            Request(i, prompt_tokens=[30 + i + j for j in range(10)],
                    max_new_tokens=8)
        )
    for _ in range(3):
        sched.step()
    assert sched.cancel(0)
    assert not sched.cancel(0)  # already gone
    done = sched.run_to_completion()
    assert [c.request_id for c in done] == [1]  # no completion for 0
    sched.manager.check_no_leaks()


# -- end-to-end: chaos through the session, stats plane byte-identical ----------


def _task(task_id, **inf_kw):
    return EvalTask(
        task_id=task_id,
        model=SLOT_MODEL,
        inference=InferenceConfig(batch_size=8, n_workers=4, **inf_kw),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    )


def test_session_chaos_run_matches_fault_free_run_byte_for_byte():
    rows = mixed_examples(30, seed=23)
    plan = ServingFaultSchedule(
        [
            ServingFault(0, 4, "page_pressure", duration=2),
            ServingFault(1, 6, "replica_crash"),
            ServingFault(2, 3, "hang", duration=4),
        ]
    )
    inf_kw = dict(
        n_replicas=3, routing="round_robin", kv_page_size=4,
        health_probe_steps=50,
    )

    def run(engine_kwargs):
        with EvalSession(engine_kwargs=engine_kwargs) as session:
            res = session.run_task(rows, _task("chaos", **inf_kw))
            (snap,) = session.serving_stats()
        return res, snap

    base_res, base_snap = run({**SLOT_KW, "kv_page_size": 4})
    chaos_res, chaos_snap = run(
        {**SLOT_KW, "kv_page_size": 4, "fault_plan": plan}
    )
    assert not chaos_res.failures  # zero lost requests
    assert chaos_snap["errors"] == 0
    for name in base_res.metrics:
        assert _mv_tuple(chaos_res.metrics[name]) == _mv_tuple(
            base_res.metrics[name]
        )
    assert chaos_snap["restarts"] >= 1
    assert chaos_snap["batcher"]["preemptions"] >= 1
    assert len(plan.injected) == 3
    assert chaos_snap["completed"] == base_snap["completed"]
