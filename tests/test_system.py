"""End-to-end system behaviour: the paper's §5.6 workflow against the
LOCAL JAX engine (reduced arch served through continuous batching), plus
the evaluation-restart ("cache as FT journal") property."""

import dataclasses as dc

import numpy as np
import pytest

from repro.core import (
    CachePolicy,
    EngineModelConfig,
    EvalRunner,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    SimulatedAPIEngine,
    StatisticsConfig,
)
from repro.data import qa_examples


@pytest.fixture(scope="module")
def local_task_rows():
    return qa_examples(10, seed=2)


def _task(tmp_path, provider="local", model="qwen3-4b"):
    return EvalTask(
        task_id="e2e-local",
        model=EngineModelConfig(
            provider=provider, model_name=model, max_tokens=8, reduced=True
        ),
        inference=InferenceConfig(
            batch_size=5, n_workers=2, cache_dir=str(tmp_path / "cache")
        ),
        metrics=(
            MetricConfig("token_f1"),
            MetricConfig("embedding_similarity", type="semantic"),
        ),
        statistics=StatisticsConfig(bootstrap_iterations=100, ci_method="percentile"),
    )


def test_local_jax_engine_end_to_end(tmp_path, local_task_rows):
    """The paper's pipeline with inference running ON the accelerator
    substrate (reduced qwen3-4b through the continuous-batching scheduler)."""
    judge = SimulatedAPIEngine(
        EngineModelConfig(provider="openai", model_name="gpt-4o")
    )
    judge.initialize()
    runner = EvalRunner(judge_engine=judge)
    res = runner.evaluate(local_task_rows, _task(tmp_path))
    assert len(res.responses) == 10
    assert res.metrics["token_f1"].n == 10
    ci = res.metrics["token_f1"].ci
    assert ci[0] <= res.metrics["token_f1"].value <= ci[1]


def test_eval_restart_resumes_from_cache(tmp_path, local_task_rows):
    """A killed evaluation re-run costs zero new inference (the paper's
    caching story doubles as restart fault tolerance)."""
    task = _task(tmp_path)
    runner = EvalRunner()
    r1 = runner.evaluate(local_task_rows, task)  # populates cache

    # "restart": same task resumes entirely from cache
    r2 = runner.evaluate(local_task_rows, task)
    assert r2.cache_stats["hit_rate"] == 1.0
    np.testing.assert_array_equal(r1.scores["token_f1"], r2.scores["token_f1"])

    # metric iteration in replay mode: new metric, no engine calls
    t3 = dc.replace(
        task,
        metrics=task.metrics + (MetricConfig("rouge_l"),),
        inference=dc.replace(task.inference, cache_policy=CachePolicy.REPLAY),
    )
    r3 = runner.evaluate(local_task_rows, t3)
    assert "rouge_l" in r3.metrics
    assert r3.cache_stats["hit_rate"] == 1.0
