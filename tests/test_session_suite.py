"""EvalSession + stage pipeline + EvalSuite: engine reuse, suite pairwise
comparison, legacy-shim equivalence, stage swaps, middleware."""

import numpy as np
import pytest

from repro.core import (
    CostBudgetExceeded,
    EngineModelConfig,
    EvalRunner,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    MetricConfig,
    Middleware,
    RunTracker,
    SimulatedAPIEngine,
    StatisticsConfig,
    compare_scores,
    rescore_stages,
)
from repro.data import mixed_examples

M_A = EngineModelConfig(provider="openai", model_name="gpt-4o-mini")
M_B = EngineModelConfig(provider="anthropic", model_name="claude-3-haiku")


def _task(tmp_path, task_id="t", model=M_A, **inf_kw) -> EvalTask:
    return EvalTask(
        task_id=task_id,
        model=model,
        inference=InferenceConfig(
            batch_size=8, n_workers=3,
            cache_dir=str(tmp_path / f"cache-{task_id}-{model.model_name}"),
            **inf_kw,
        ),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(bootstrap_iterations=200),
    )


@pytest.fixture
def init_counter(monkeypatch):
    counts: dict[str, int] = {}
    orig = SimulatedAPIEngine.initialize

    def counting(self):
        counts[self.model.model_name] = counts.get(self.model.model_name, 0) + 1
        orig(self)

    monkeypatch.setattr(SimulatedAPIEngine, "initialize", counting)
    return counts


def test_session_reuses_engine_across_tasks(tmp_path, init_counter):
    rows = mixed_examples(20, seed=3)
    with EvalSession() as session:
        session.run_task(rows, _task(tmp_path, "a"))
        session.run_task(rows, _task(tmp_path, "b"))
        assert len(session.engines) == 1
    assert init_counter == {"gpt-4o-mini": 1}


def test_suite_two_models_two_tasks(tmp_path, init_counter):
    """Acceptance criterion: 2 models × 2 tasks, each engine initialized
    exactly once, SuiteResult has a pairwise Comparison per shared metric."""
    rows1 = mixed_examples(30, seed=3)
    rows2 = mixed_examples(25, seed=7)
    suite = (
        EvalSuite("reg")
        .add_task(_task(tmp_path, "qa"), rows1)
        .add_task(_task(tmp_path, "qa2"), rows2)
        .sweep_models([M_A, M_B])
    )
    with EvalSession() as session:
        res = session.run_suite(suite)
        assert init_counter == {"gpt-4o-mini": 1, "claude-3-haiku": 1}

    assert res.models == ["gpt-4o-mini", "claude-3-haiku"]
    assert res.tasks == ["qa", "qa2"]
    assert len(res.results) == 4
    for task_id in res.tasks:
        for metric in ("exact_match", "token_f1"):
            cmp = res.comparison(task_id, metric, "gpt-4o-mini", "claude-3-haiku")
            assert cmp.metric == metric
            assert 0.0 <= cmp.test.p_value <= 1.0
    assert res.accounting["tasks"] == 4
    md = res.to_markdown()
    assert "| model |" in md and "gpt-4o-mini" in md


def test_suite_comparison_matches_direct_compare_scores(tmp_path):
    rows = mixed_examples(30, seed=5)
    suite = (
        EvalSuite().add_task(_task(tmp_path, "qa"), rows).sweep_models([M_A, M_B])
    )
    with EvalSession() as session:
        res = session.run_suite(suite)
    ra = res.result("gpt-4o-mini", "qa")
    rb = res.result("claude-3-haiku", "qa")
    direct = compare_scores(
        "token_f1", ra.scores["token_f1"], rb.scores["token_f1"],
        confidence=0.95, n_boot=200, seed=0,
    )
    via_suite = res.comparison("qa", "token_f1", "gpt-4o-mini", "claude-3-haiku")
    assert via_suite.diff == direct.diff
    assert via_suite.test.p_value == direct.test.p_value
    assert via_suite.diff_ci == direct.diff_ci
    assert via_suite.effect.value == direct.effect.value


def test_runner_shim_matches_session_path(tmp_path):
    """The legacy shim returns field-identical EvalResult to a fresh
    session running the default stage pipeline."""
    rows = mixed_examples(25, seed=9)
    r_shim = EvalRunner().evaluate(rows, _task(tmp_path, "shim"))
    with EvalSession() as session:
        r_sess = session.run_task(rows, _task(tmp_path, "sess"))

    assert r_shim.responses == r_sess.responses
    for m in r_shim.scores:
        np.testing.assert_array_equal(r_shim.scores[m], r_sess.scores[m])
    for m, mv in r_shim.metrics.items():
        sv = r_sess.metrics[m]
        assert (mv.value, mv.ci, mv.ci_method, mv.n, mv.n_unscored) == (
            sv.value, sv.ci, sv.ci_method, sv.n, sv.n_unscored
        )
    assert r_shim.failures == r_sess.failures
    # per-call stats: identical between shim and session.  The inference
    # service deduplicates repeated prompts within a task (mixed_examples
    # repeats 2 of the 25), so unique work is billed once and the rest is
    # accounted as coalesced — deterministically, via the stage-local
    # single-flight table.
    assert r_shim.engine_stats["calls"] == r_sess.engine_stats["calls"]
    assert (
        r_sess.engine_stats["calls"] + r_sess.engine_stats["coalesced"] == 25
    )
    assert r_shim.engine_stats["total_cost"] == pytest.approx(
        r_sess.engine_stats["total_cost"]
    )
    assert r_shim.engine_stats["pool"] == r_sess.engine_stats["pool"]
    assert r_shim.cache_stats["hits"] == r_sess.cache_stats["hits"] == 0
    # one cache write per unique answered prompt
    assert r_shim.cache_stats["writes"] == r_sess.cache_stats["writes"]
    assert r_sess.cache_stats["writes"] == r_sess.engine_stats["calls"]


def test_rescore_stage_swap_zero_engine_calls(tmp_path):
    rows = mixed_examples(20, seed=11)
    task = _task(tmp_path, "base")
    with EvalSession() as session:
        full = session.run_task(rows, task)
        calls_before = session.accounting.engine_calls
        re_task = task.with_metrics(MetricConfig("rouge_l"), MetricConfig("bleu"))
        res = session.run_task(
            rows, re_task, stages=rescore_stages(full.responses)
        )
        assert session.accounting.engine_calls == calls_before
    assert set(res.metrics) == {"rouge_l", "bleu"}
    assert res.engine_stats["calls"] == 0
    # re-scoring the same metric reproduces the full-pipeline scores, and a
    # lexical-only rescore session never constructs an engine at all
    with EvalSession() as session:
        again = session.run_task(
            rows, task, stages=rescore_stages(full.responses)
        )
        assert len(session.engines) == 0
    np.testing.assert_array_equal(
        again.scores["token_f1"], full.scores["token_f1"]
    )


def test_cache_stats_are_per_task_deltas(tmp_path):
    rows = mixed_examples(15, seed=13)
    task = _task(tmp_path, "warm")
    with EvalSession() as session:
        r1 = session.run_task(rows, task)
        r2 = session.run_task(rows, task)
    assert r1.cache_stats["hit_rate"] == 0.0
    assert r2.cache_stats["hit_rate"] == 1.0
    assert r2.cache_stats["writes"] == 0


def test_cost_budget_middleware_aborts(tmp_path):
    rows = mixed_examples(40, seed=17)
    with EvalSession(cost_budget_usd=1e-9) as session:
        with pytest.raises(CostBudgetExceeded):
            session.run_task(rows, _task(tmp_path, "budget"))


def test_middleware_hooks_fire_in_order(tmp_path):
    events: list[str] = []

    class Recorder(Middleware):
        def on_task_start(self, task, rows, session):
            events.append("task_start")

        def on_stage_start(self, stage, art, session):
            events.append(f"start:{stage.name}")

        def on_stage_end(self, stage, art, session):
            events.append(f"end:{stage.name}")

        def on_task_end(self, task, result, session):
            events.append("task_end")

    rows = mixed_examples(10, seed=19)
    with EvalSession(middleware=[Recorder()]) as session:
        session.run_task(rows, _task(tmp_path, "mw"))
    assert events == [
        "task_start",
        "start:prepare", "end:prepare",
        "start:infer", "end:infer",
        "start:metrics", "end:metrics",
        "start:stats", "end:stats",
        "task_end",
    ]


def test_closed_session_rejects_work(tmp_path):
    session = EvalSession()
    session.close()
    with pytest.raises(RuntimeError):
        session.run_task([], _task(tmp_path, "closed"))


def test_suite_tracking_roundtrip(tmp_path):
    rows = mixed_examples(15, seed=23)
    suite = (
        EvalSuite("tracked")
        .add_task(_task(tmp_path, "qa"), rows)
        .sweep_models([M_A, M_B])
    )
    with EvalSession() as session:
        res = session.run_suite(suite)
    tracker = RunTracker(str(tmp_path / "runs"))
    suite_id = tracker.log_suite(res, experiment="unit")
    assert suite_id in tracker.list_runs()
    report = (tmp_path / "runs" / suite_id / "report.md").read_text()
    assert "Suite report: tracked" in report
