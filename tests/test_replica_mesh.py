"""Replica device placement over a JAX mesh (ISSUE 7).

The partitioning logic is pure and runs anywhere; the placement tests
need more than one XLA device and are skipped on the deliberately
single-device main suite (tests/conftest.py).  CI runs them in the
dedicated multi-device job with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — see
tests/SKIPS.md.
"""

import jax
import pytest

from repro.core.config import EngineModelConfig
from repro.core.engines import InferenceRequest, LocalJaxEngine
from repro.core.service import InferenceService
from repro.launch.mesh import make_replica_mesh, replica_device_groups

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="single-device process (by design for the test suite)",
)

LOCAL_MODEL = EngineModelConfig(provider="local", model_name="qwen3-4b")
ENGINE_KW = {"n_slots": 2, "max_len": 64}
PROMPTS = [f"replica mesh prompt {i}" for i in range(4)]


# -- pure partitioning ----------------------------------------------------------


def test_device_groups_partition_contiguously_and_evenly():
    devs = [object() for _ in range(8)]
    groups = replica_device_groups(2, devs)
    assert groups == [tuple(devs[:4]), tuple(devs[4:])]
    sizes = [len(g) for g in replica_device_groups(3, devs)]
    assert sizes == [3, 3, 2]
    assert [d for g in replica_device_groups(3, devs) for d in g] == devs


def test_device_groups_wrap_when_oversubscribed():
    devs = [object(), object()]
    groups = replica_device_groups(5, devs)
    assert [g[0] for g in groups] == [
        devs[0], devs[1], devs[0], devs[1], devs[0]
    ]
    assert all(len(g) == 1 for g in groups)


def test_device_groups_reject_zero_replicas():
    with pytest.raises(ValueError, match="n_replicas"):
        replica_device_groups(0, [object()])


def test_make_replica_mesh_single_device():
    mesh = make_replica_mesh(jax.devices()[:1])
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape == {"data": 1, "model": 1}


def test_make_replica_mesh_rejects_uneven_data_split():
    with pytest.raises(ValueError, match="does not divide"):
        make_replica_mesh([object(), object(), object()], data=2)


# -- multi-device placement -----------------------------------------------------


def _decode_all(engine, prompts=PROMPTS):
    reqs = [InferenceRequest(p, max_tokens=4) for p in prompts]
    return [r.text for r in engine.infer_batch(reqs)]


@multi_device
def test_pinned_replicas_are_bit_identical_to_default_device():
    """One replica per device: greedy decode is device-placement
    independent, so every pinned replica reproduces the default-device
    tokens bit-for-bit (the foundation of the replica parity contract)."""
    base = LocalJaxEngine(LOCAL_MODEL, **ENGINE_KW)
    base.initialize()
    want = _decode_all(base)
    for group in replica_device_groups(2):
        eng = LocalJaxEngine(LOCAL_MODEL, devices=group[:1], **ENGINE_KW)
        eng.initialize()
        assert _decode_all(eng) == want
        eng.shutdown()
    base.shutdown()


@multi_device
def test_replica_mesh_uses_distinct_device_groups():
    groups = replica_device_groups(2)
    assert set(groups[0]).isdisjoint(groups[1])
    meshes = [make_replica_mesh(g) for g in groups]
    for mesh, group in zip(meshes, groups):
        assert set(mesh.devices.flat) == set(group)


@multi_device
def test_sharded_replica_serves_valid_completions():
    """A tensor-parallel replica (several devices under one ("data",
    "model") mesh with SERVE_RULES) must complete requests; sharded float
    reductions may legally flip greedy argmax ties, so this asserts
    serving validity, not bit-parity with the single-device path."""
    devs = tuple(jax.devices()[:2])
    eng = LocalJaxEngine(LOCAL_MODEL, devices=devs, **ENGINE_KW)
    eng.initialize()
    assert eng._scheduler.rules is not None
    assert eng._scheduler.rules.mesh.shape["model"] == 2
    texts = _decode_all(eng)
    assert len(texts) == len(PROMPTS)
    assert all(isinstance(t, str) for t in texts)
    eng.shutdown()


@multi_device
def test_service_fleet_on_distinct_devices_matches_single_replica():
    """Two pinned replicas behind one service front return byte-identical
    responses to a single default-device engine, for every routing
    policy."""
    base = LocalJaxEngine(LOCAL_MODEL, **ENGINE_KW)
    base.initialize()
    want = {p: t for p, t in zip(PROMPTS, _decode_all(base))}
    base.shutdown()
    groups = replica_device_groups(2)
    for routing in ("least_loaded", "prefix_affinity", "round_robin"):
        fleet = [
            LocalJaxEngine(LOCAL_MODEL, devices=g[:1], **ENGINE_KW)
            for g in groups
        ]
        for e in fleet:
            e.initialize()
        svc = InferenceService(
            engines=fleet, routing=routing, max_batch_wait_ms=0.0,
            name=f"mesh-{routing}",
        )
        tickets = {
            p: svc.submit(InferenceRequest(p, max_tokens=4), key=p)
            for p in PROMPTS
        }
        got = {p: t.result(timeout=120.0).text for p, t in tickets.items()}
        assert got == want, routing
        svc.close()
