"""Paged KV cache through the serving stack (ISSUE 8): suite-level
byte-parity across page sizes and replica counts, prefix-sharing stats
surfaced in reports, and the simulated engine's paged accounting."""

import pytest

from repro.core import (
    EngineModelConfig,
    EvalSession,
    EvalSuite,
    EvalTask,
    InferenceConfig,
    InferenceRequest,
    MetricConfig,
    SimulatedSlotEngine,
    StatisticsConfig,
)

SLOT_MODEL = EngineModelConfig(provider="slotsim", model_name="slot-sim")
SLOT_MODEL_B = EngineModelConfig(provider="slotsim", model_name="slot-sim-b")
SLOT_KW = {"n_slots": 4, "step_ms": 0.0}

HEADER = " ".join(f"shot{i} demo answer span" for i in range(10))  # 40 words


def _shared_prefix_rows(n):
    """Rows whose prompts share a 40-word few-shot header: with 16-token
    pages the first two pages of every prompt are chain-identical."""
    return [
        {"question": f"{HEADER} question {i} please", "reference": f"ref {i}"}
        for i in range(n)
    ]


def _task(task_id="paged", model=SLOT_MODEL, **inf_kw):
    return EvalTask(
        task_id=task_id,
        model=model,
        inference=InferenceConfig(batch_size=8, n_workers=4, **inf_kw),
        metrics=(MetricConfig("exact_match"), MetricConfig("token_f1")),
        statistics=StatisticsConfig(
            bootstrap_iterations=200, ci_method="percentile"
        ),
    )


def _mv_tuple(mv):
    return (mv.value, mv.ci, mv.ci_method, mv.n, mv.n_unscored)


def _cmp_tuple(c):
    return (c.diff, c.diff_ci, c.test.p_value, c.effect.value)


# -- simulated engine, driven directly -------------------------------------------


def test_sim_engine_prefix_sharing_counters():
    eng = SimulatedSlotEngine(SLOT_MODEL, kv_page_size=16, **SLOT_KW)
    eng.initialize()
    rows = _shared_prefix_rows(6)
    rids = [
        eng.stream_submit(InferenceRequest(r["question"], 8, 0.0))
        for r in rows
    ]
    done = {}
    while len(done) < len(rids):
        for rid, resp in eng.stream_pump():
            done[rid] = resp
    st = eng.stats
    # every admission after the first reuses the 2-page (32-word) header
    assert st.prefix_pages_hit == 2 * (len(rows) - 1)
    assert st.prefix_tokens_saved == 32 * (len(rows) - 1)
    assert st.as_dict()["prefix_tokens_saved"] == st.prefix_tokens_saved
    eng._pages.check_no_leaks()


def test_sim_engine_paged_responses_match_unpaged():
    def run(**kw):
        eng = SimulatedSlotEngine(SLOT_MODEL, **SLOT_KW, **kw)
        eng.initialize()
        reqs = {
            eng.stream_submit(InferenceRequest(r["question"], 8, 0.0)): r[
                "question"
            ]
            for r in _shared_prefix_rows(8)
        }
        out = {}
        while eng.stream_pending():
            for rid, resp in eng.stream_pump():
                out[reqs[rid]] = resp.text
        return out

    assert run() == run(kv_page_size=16) == run(kv_page_size=64)


def test_sim_engine_prefills_deferred_counts_once():
    """Regression (ISSUE 8 S1), simulated-engine flavour: 4 one-step
    requests behind a cap of 1 on 2 slots wait 3 rounds total — not the
    3 + 2 + 1 = 6 the per-neighbour accounting used to report."""
    eng = SimulatedSlotEngine(
        SLOT_MODEL, n_slots=2, step_ms=0.0, max_prefills_per_step=1
    )
    eng.initialize()
    rids = [
        eng.stream_submit(InferenceRequest(f"pinned workload {i}", 1, 0.0))
        for i in range(4)
    ]
    done = {}
    while eng.stream_pending():
        for rid, resp in eng.stream_pump():
            done[rid] = resp
    assert set(done) == set(rids)
    assert eng.stats.admissions == 4
    assert eng.stats.prefills_deferred == 3


# -- suite-level byte parity -----------------------------------------------------


def test_suite_byte_parity_across_page_sizes():
    """The golden suite (lexical metrics + comparison matrix) is
    byte-identical across unpaged and 16-/64-token paged caches — the
    cache layout is stats-plane-invisible."""
    rows = _shared_prefix_rows(40)
    models = [SLOT_MODEL, SLOT_MODEL_B]

    def run(page_size):
        suite = (
            EvalSuite(f"ps{page_size}")
            .add_task(_task(kv_page_size=page_size), rows)
            .sweep_models(models)
        )
        # fresh session per config: the registry keys engines on their
        # constructor kwargs, a shared session would reuse nothing anyway
        with EvalSession(engine_kwargs=SLOT_KW) as session:
            res = session.run_suite(suite, parallel_jobs=2)
            snaps = session.serving_stats()
        return res, snaps

    base, _ = run(0)
    for ps in (16, 64):
        got, snaps = run(ps)
        for key, res in base.results.items():
            assert got.results[key].responses == res.responses, key
            for m, mv in res.metrics.items():
                assert _mv_tuple(got.results[key].metrics[m]) == _mv_tuple(mv)
        for task_id, metrics in base.comparisons.items():
            for metric, cells in metrics.items():
                for pair, cmp in cells.items():
                    assert _cmp_tuple(
                        got.comparisons[task_id][metric][pair]
                    ) == _cmp_tuple(cmp), (task_id, metric, pair)
        if ps == 16:
            # 64-token pages can't share a ~44-word prompt; the 16-token
            # run actually shared prefixes while agreeing byte-wise
            assert sum(s["batcher"]["prefix_pages_hit"] for s in snaps) > 0


@pytest.mark.parametrize("n_replicas", [2, 4])
def test_replica_parity_with_paged_cache(n_replicas):
    """Paging composes with the replica fabric: n replicas, each with its
    own page pool, still produce byte-identical suite output."""
    rows = _shared_prefix_rows(32)

    def run(n):
        suite = EvalSuite(f"rep{n}").add_task(
            _task(n_replicas=n, kv_page_size=16), rows
        )
        with EvalSession(engine_kwargs=SLOT_KW) as session:
            res = session.run_suite(suite, parallel_jobs=2)
            snaps = session.serving_stats()
        return res, snaps

    base, _ = run(1)
    got, snaps = run(n_replicas)
    for key, res in base.results.items():
        assert got.results[key].responses == res.responses, key
        for m, mv in res.metrics.items():
            assert _mv_tuple(got.results[key].metrics[m]) == _mv_tuple(mv)
    (snap,) = snaps
    assert snap["replicas"] == n_replicas
    assert snap["batcher"]["prefix_pages_hit"] > 0


def test_suite_markdown_reports_prefix_columns():
    rows = _shared_prefix_rows(20)
    suite = EvalSuite("pagedmd").add_task(
        _task(task_id="qa", kv_page_size=16), rows
    )
    with EvalSession(engine_kwargs=SLOT_KW) as session:
        sres = session.run_suite(suite)
        (snap,) = session.serving_stats()
    md = sres.to_markdown()
    assert "| prefix hits |" in md and "| prefix tok saved |" in md
    saved = snap["batcher"]["prefix_tokens_saved"]
    assert saved > 0
    assert f" {saved} " in md  # the counter lands in the table row
