"""Assigned-architecture configs: exact numbers, plausible parameter counts."""

import pytest

from repro.configs import ARCHS, SHAPES, applicable_shapes, get_config
from repro.models import params as pm
from repro.models.model import active_param_count, build_model

# (arch, expected total params, rel tolerance).  Expectations are the
# published sizes; hash-tokenizer vocab padding and stubbed frontends keep
# us within tolerance.
EXPECTED_PARAMS = {
    "whisper-large-v3": (1.5e9, 0.35),   # decoder+encoder backbone only
    "qwen1.5-110b": (111e9, 0.10),
    "qwen3-4b": (4.0e9, 0.15),
    "minicpm3-4b": (4.0e9, 0.25),
    "qwen2.5-32b": (32.5e9, 0.10),
    "zamba2-7b": (7.2e9, 0.25),
    "paligemma-3b": (2.9e9, 0.30),       # vision tower stubbed
    "mamba2-2.7b": (2.7e9, 0.15),
    "qwen3-moe-30b-a3b": (30.5e9, 0.15),
    "deepseek-v2-236b": (236e9, 0.15),
}


def test_all_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_counts(arch):
    cfg = get_config(arch)
    model = build_model(cfg)
    n = pm.param_count(model.param_specs())
    expect, tol = EXPECTED_PARAMS[arch]
    assert abs(n - expect) / expect < tol, (
        f"{arch}: {n:,} params vs expected {expect:,.0f}"
    )


def test_active_params_moe():
    cfg = get_config("qwen3-moe-30b-a3b")
    model = build_model(cfg)
    specs = model.param_specs()
    total = pm.param_count(specs)
    active = active_param_count(cfg, specs)
    assert active < total * 0.35
    assert abs(active - 3.3e9) / 3.3e9 < 0.4  # "a3b" = ~3B active

    ds = get_config("deepseek-v2-236b")
    dspecs = build_model(ds).param_specs()
    dactive = active_param_count(ds, dspecs)
    assert abs(dactive - 21e9) / 21e9 < 0.35  # paper: 21B active


def test_shape_grid():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].tokens == 4096 * 256
    cells = [(a, s) for a in ARCHS for s in applicable_shapes(get_config(a))]
    # 10 archs x 3 shapes + long_500k for the two sub-quadratic archs
    assert len(cells) == 32
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"mamba2-2.7b", "zamba2-7b"}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_configs_are_small(arch):
    r = get_config(arch).reduced()
    n = pm.param_count(build_model(r).param_specs())
    assert n < 5e6, f"{arch} reduced config too big for CPU smoke: {n:,}"


def test_padded_vocab_divides_tp16():
    for cfg in ARCHS.values():
        assert cfg.padded_vocab % 256 == 0
        assert cfg.padded_vocab >= cfg.vocab_size
