"""DeltaLite (ACID log, time travel, CAS) and the 5-policy response cache."""

import os
import threading

import pytest

from repro.core import CacheEntry, CacheMiss, CachePolicy, ResponseCache
from repro.storage import ChunkManifest, DeltaLite


def _rows(lo, hi):
    return [{"prompt_hash": f"k{i}", "value": i} for i in range(lo, hi)]


def test_append_read_time_travel(tmp_path):
    t = DeltaLite(str(tmp_path / "t"), key_column="prompt_hash")
    v0 = t.append(_rows(0, 3))
    v1 = t.append(_rows(3, 5))
    assert (v0, v1) == (0, 1)
    assert len(t.read()) == 5
    assert len(t.read(version=0)) == 3  # time travel
    assert t.latest_version() == 1
    hist = t.history()
    assert [h["version"] for h in hist] == [0, 1]


def test_lookup_cas_pruning(tmp_path):
    t = DeltaLite(str(tmp_path / "t"), key_column="prompt_hash")
    t.append(_rows(0, 100))
    t.append([{"prompt_hash": "k5", "value": 999}])  # upsert: later wins
    assert t.lookup("k5")["value"] == 999
    assert t.lookup("k99")["value"] == 99
    assert t.lookup("missing") is None
    assert "k42" in t.keys()


def test_overwrite_and_compact(tmp_path):
    t = DeltaLite(str(tmp_path / "t"), key_column="prompt_hash")
    t.append(_rows(0, 4))
    t.append([{"prompt_hash": "k1", "value": -1}])
    t.compact()
    rows = t.read()
    assert len(rows) == 4  # deduped latest-wins
    assert {r["value"] for r in rows if r["prompt_hash"] == "k1"} == {-1}
    # old version still readable (time travel survives compaction)
    assert len(t.read(version=0)) == 4

    t.overwrite([{"prompt_hash": "solo", "value": 0}])
    assert len(t.read()) == 1


def test_concurrent_appends_all_commit(tmp_path):
    t = DeltaLite(str(tmp_path / "t"), key_column="prompt_hash")

    def writer(i):
        DeltaLite(str(tmp_path / "t"), key_column="prompt_hash").append(
            [{"prompt_hash": f"w{i}", "value": i}]
        )

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(t.read()) == 8
    assert t.latest_version() == 7  # optimistic concurrency: all distinct


def test_partial_write_invisible(tmp_path):
    """A segment without a log commit must not be observed (crash safety)."""
    t = DeltaLite(str(tmp_path / "t"), key_column="prompt_hash")
    t.append(_rows(0, 2))
    # simulate a crashed writer: orphan segment file, no log entry
    with open(tmp_path / "t" / "data" / "part-orphan.jsonl.gz", "wb") as f:
        f.write(b"garbage")
    assert len(t.read()) == 2


def test_deterministic_version_race_retries_and_both_commit(tmp_path):
    """Two writers that observe the SAME latest version must race on the
    version file: exactly one wins os.link, the loser retries with the next
    version, and both rows land."""
    barrier = threading.Barrier(2, timeout=10)
    version_calls: dict[int, int] = {}

    class RacingDelta(DeltaLite):
        def latest_version(self):
            v = super().latest_version()
            me = threading.get_ident()
            version_calls[me] = version_calls.get(me, 0) + 1
            if version_calls[me] == 1:
                barrier.wait()  # both writers now commit the same version
            return v

    errors: list[Exception] = []

    def writer(i: int) -> None:
        try:
            RacingDelta(str(tmp_path / "t"), key_column="prompt_hash").append(
                [{"prompt_hash": f"w{i}", "value": i}]
            )
        except Exception as e:  # pragma: no cover - surfaced by assert below
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(i,)) for i in range(2)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors
    t = DeltaLite(str(tmp_path / "t"), key_column="prompt_hash")
    assert t.latest_version() == 1
    assert {r["prompt_hash"] for r in t.read()} == {"w0", "w1"}
    # the race loser called latest_version a second time (the retry)
    assert sorted(version_calls.values()) == [1, 2]


def test_orphaned_valid_segment_invisible_everywhere(tmp_path):
    """A writer dying between segment write and log commit leaves a fully
    valid but unreferenced segment: readers, point lookups, key listings
    and time travel must never observe it."""
    t = DeltaLite(str(tmp_path / "t"), key_column="prompt_hash")
    t.append(_rows(0, 3))
    # crash exactly between _write_segment and _commit
    t._write_segment([{"prompt_hash": "ghost", "value": 666}])
    assert len(os.listdir(tmp_path / "t" / "data")) == 2  # file is on disk
    assert len(t.read()) == 3
    assert t.lookup("ghost") is None
    assert "ghost" not in t.keys()
    assert len(t.read(version=0)) == 3
    assert t.latest_version() == 0
    # and a later commit still doesn't resurrect it
    t.append(_rows(3, 4))
    assert t.lookup("ghost") is None
    assert len(t.read()) == 4


def test_chunk_manifest_isolation_and_latest_wins(tmp_path):
    m1 = ChunkManifest(str(tmp_path / "spill"), run_key="run-a")
    m2 = ChunkManifest(str(tmp_path / "spill"), run_key="run-b")
    m1.record(0, {"n_rows": 10})
    m2.record(0, {"n_rows": 99})
    assert m1.completed()[0]["n_rows"] == 10  # runs are isolated
    assert m2.completed()[0]["n_rows"] == 99
    m1.record(0, {"n_rows": 11})  # duplicate commit: latest wins
    assert m1.completed()[0]["n_rows"] == 11
    assert set(m1.completed()) == {0}


# ---------------------------------------------------------------------------
# response cache policies
# ---------------------------------------------------------------------------


def _entry(key: str, text: str = "resp") -> CacheEntry:
    return CacheEntry(
        prompt_hash=key, model_name="m", provider="p", prompt_text="q",
        response_text=text, input_tokens=3, output_tokens=2,
        latency_ms=1.0, created_at=0.0,
    )


def test_enabled_policy(tmp_path):
    c = ResponseCache(str(tmp_path / "c"), CachePolicy.ENABLED)
    assert c.lookup("a") is None
    c.put([_entry("a")])
    assert c.lookup("a").response_text == "resp"
    assert c.stats()["hits"] == 1


def test_read_only_never_writes(tmp_path):
    c = ResponseCache(str(tmp_path / "c"), CachePolicy.READ_ONLY)
    c.put([_entry("a")])
    assert c.lookup("a") is None
    assert c.stats()["writes"] == 0


def test_write_only_never_reads(tmp_path):
    c = ResponseCache(str(tmp_path / "c"), CachePolicy.WRITE_ONLY)
    c.put([_entry("a")])
    assert c.lookup("a") is None
    c2 = ResponseCache(str(tmp_path / "c"), CachePolicy.ENABLED)
    assert c2.lookup("a") is not None  # warmed


def test_replay_raises_on_miss(tmp_path):
    warm = ResponseCache(str(tmp_path / "c"), CachePolicy.ENABLED)
    warm.put([_entry("a")])
    c = ResponseCache(str(tmp_path / "c"), CachePolicy.REPLAY)
    assert c.lookup("a") is not None
    with pytest.raises(CacheMiss):
        c.lookup("missing")


def test_ttl_expiry(tmp_path):
    c = ResponseCache(str(tmp_path / "c"), CachePolicy.ENABLED)
    e = _entry("a")
    e.ttl_days = 1
    e.created_at = 0.0  # 1970 — long expired
    c.put([e])
    assert c.lookup("a") is None


def test_cross_process_visibility(tmp_path):
    c1 = ResponseCache(str(tmp_path / "c"), CachePolicy.ENABLED)
    c2 = ResponseCache(str(tmp_path / "c"), CachePolicy.ENABLED)
    c1.put([_entry("a")])
    # c2 built before the write: refresh picks up the new version
    assert c2.lookup("a") is not None
